//! Parameter storage: one flat `Vec<f32>` per tensor, aligned with the
//! model's [`super::meta::ModelMeta`] layer order.

use super::meta::{LayerRole, ModelMeta};
use crate::util::pool::chunked_reduce;
use crate::util::rng::Pcg64;

/// Element-chunk length for the deterministic parallel reduction. Fixed (it
/// must never depend on the worker count) and large enough that per-chunk
/// dispatch overhead is negligible next to the FMA work.
const REDUCE_CHUNK: usize = 16 * 1024;

/// All trainable tensors of one model replica.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamStore {
    tensors: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Initialize parameters the same way `python/compile/model.py` does:
    /// He-uniform for conv/dense kernels, zeros for biases/norm-offsets,
    /// ones for norm-scales, scaled-normal for embeddings.
    ///
    /// Layer `i` draws from `rng.fork(i)` so the stream per tensor is
    /// independent of every other tensor's size — this is the cross-language
    /// reproducibility contract with python `init_params`.
    pub fn init(meta: &ModelMeta, rng: &Pcg64) -> Self {
        let tensors = meta
            .layers
            .iter()
            .enumerate()
            .map(|(i, layer)| {
                let mut r = rng.fork(i as u64);
                let n = layer.size();
                match layer.role {
                    LayerRole::ConvKernel | LayerRole::DenseKernel => {
                        let fan_in = layer.segment_len() as f64;
                        let mut bound = (6.0 / fan_in).sqrt() as f32;
                        // Residual-branch output convs start near zero so
                        // each block is near-identity at init (fixup-style;
                        // the models have no batch norm). Without this the
                        // deep residual stack's activations — and the
                        // initial loss — explode.
                        if layer.name.contains("block") && layer.name.ends_with("conv2.kernel")
                        {
                            bound *= 0.1;
                        }
                        (0..n).map(|_| (r.f32() * 2.0 - 1.0) * bound).collect()
                    }
                    LayerRole::Bias => vec![0.0; n],
                    LayerRole::Norm => {
                        if layer.name.ends_with("scale") {
                            vec![1.0; n]
                        } else {
                            vec![0.0; n]
                        }
                    }
                    LayerRole::Embedding => {
                        let mut v = r.normal_vec(n);
                        v.iter_mut().for_each(|x| *x *= 0.02);
                        v
                    }
                }
            })
            .collect();
        ParamStore { tensors }
    }

    /// Zero-filled store with the same geometry (for gradient accumulators).
    pub fn zeros_like(meta: &ModelMeta) -> Self {
        ParamStore { tensors: meta.layers.iter().map(|l| vec![0.0; l.size()]).collect() }
    }

    /// Wrap existing tensors (shape-checked against `meta`).
    pub fn from_tensors(meta: &ModelMeta, tensors: Vec<Vec<f32>>) -> Self {
        assert_eq!(tensors.len(), meta.layers.len());
        for (t, l) in tensors.iter().zip(&meta.layers) {
            assert_eq!(t.len(), l.size(), "tensor '{}' size mismatch", l.name);
        }
        ParamStore { tensors }
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True when the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Tensor `i` as a slice.
    pub fn tensor(&self, i: usize) -> &[f32] {
        &self.tensors[i]
    }

    /// Consume the store into its owned per-tensor buffers (layer order).
    ///
    /// Lets the round engine hand a delta's buffers straight to the
    /// compressor without re-copying every tensor.
    pub fn into_tensors(self) -> Vec<Vec<f32>> {
        self.tensors
    }

    /// Mutable tensor `i`.
    pub fn tensor_mut(&mut self, i: usize) -> &mut Vec<f32> {
        &mut self.tensors[i]
    }

    /// Iterate tensors.
    pub fn iter(&self) -> impl Iterator<Item = &Vec<f32>> {
        self.tensors.iter()
    }

    /// `self += scale * other`, elementwise over all tensors.
    pub fn axpy(&mut self, scale: f32, other: &ParamStore) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b) {
                *x += scale * y;
            }
        }
    }

    /// `self *= scale`.
    pub fn scale(&mut self, scale: f32) {
        for t in &mut self.tensors {
            for x in t {
                *x *= scale;
            }
        }
    }

    /// Deterministic weighted sum across participant updates:
    /// `out[t][e] = Σ_p scales[p] · terms[p][t][e]`.
    ///
    /// Each element accumulates over `terms` in slice order, and the work is
    /// split into fixed [`REDUCE_CHUNK`]-element chunks whose geometry never
    /// depends on `workers` — so the result is bit-identical to a sequential
    /// fold for every worker count.
    ///
    /// Since the compressed-domain aggregation plane landed, the round
    /// engine folds structured updates through
    /// [`ServerAggregator`](crate::coordinator::ServerAggregator) instead
    /// of densifying into `terms`; this remains the *dense-path reference*
    /// the equivalence tests (`rust/tests/aggregation.rs`) and the
    /// `server-phase-dense` bench compare against, and the dense fold's
    /// per-element operation order is what the aggregator reproduces
    /// bit-for-bit on non-low-rank payloads.
    pub fn weighted_sum(
        meta: &ModelMeta,
        terms: &[&[Vec<f32>]],
        scales: &[f32],
        workers: usize,
    ) -> ParamStore {
        assert_eq!(terms.len(), scales.len(), "one scale per term");
        let mut out = ParamStore::zeros_like(meta);
        for term in terms {
            assert_eq!(term.len(), out.tensors.len(), "term tensor count mismatch");
        }
        let slices: Vec<&mut [f32]> =
            out.tensors.iter_mut().map(|t| t.as_mut_slice()).collect();
        chunked_reduce(workers, slices, REDUCE_CHUNK, |ti, offset, chunk| {
            for (term, &scale) in terms.iter().zip(scales) {
                let src = &term[ti][offset..offset + chunk.len()];
                for (dst, &v) in chunk.iter_mut().zip(src) {
                    *dst += scale * v;
                }
            }
        });
        out
    }

    /// `self - other` as a new store (the FL "model delta" / pseudo-gradient).
    pub fn delta(&self, other: &ParamStore) -> ParamStore {
        assert_eq!(self.tensors.len(), other.tensors.len());
        let tensors = self
            .tensors
            .iter()
            .zip(&other.tensors)
            .map(|(a, b)| a.iter().zip(b).map(|(x, y)| x - y).collect())
            .collect();
        ParamStore { tensors }
    }

    /// Global L2 norm over all tensors.
    pub fn l2_norm(&self) -> f32 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt() as f32
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;

    #[test]
    fn init_matches_meta_geometry() {
        let meta = layer_table(ModelKind::LeNet5);
        let p = ParamStore::init(&meta, &Pcg64::seeded(1));
        assert_eq!(p.len(), meta.layers.len());
        assert_eq!(p.numel(), meta.total_params());
    }

    #[test]
    fn init_deterministic_and_layer_independent() {
        let meta = layer_table(ModelKind::LeNet5);
        let a = ParamStore::init(&meta, &Pcg64::seeded(9));
        let b = ParamStore::init(&meta, &Pcg64::seeded(9));
        assert_eq!(a, b);
        let c = ParamStore::init(&meta, &Pcg64::seeded(10));
        assert_ne!(a, c);
    }

    #[test]
    fn biases_zero_scales_one() {
        let meta = layer_table(ModelKind::TinyTransformer);
        let p = ParamStore::init(&meta, &Pcg64::seeded(2));
        for (i, l) in meta.layers.iter().enumerate() {
            match l.role {
                LayerRole::Bias => assert!(p.tensor(i).iter().all(|&x| x == 0.0), "{}", l.name),
                LayerRole::Norm if l.name.ends_with("scale") => {
                    assert!(p.tensor(i).iter().all(|&x| x == 1.0), "{}", l.name)
                }
                _ => {}
            }
        }
    }

    #[test]
    fn kernel_init_within_he_bound() {
        let meta = layer_table(ModelKind::LeNet5);
        let p = ParamStore::init(&meta, &Pcg64::seeded(3));
        let i = meta.index_of("fc1.kernel").unwrap();
        let bound = (6.0f32 / 256.0).sqrt();
        assert!(p.tensor(i).iter().all(|&x| x.abs() <= bound));
        // and not degenerate
        let max = p.tensor(i).iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(max > 0.5 * bound);
    }

    #[test]
    fn weighted_sum_matches_sequential_fold_bitwise() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut rng = Pcg64::seeded(31);
        let updates: Vec<Vec<Vec<f32>>> = (0..5)
            .map(|_| meta.layers.iter().map(|l| rng.normal_vec(l.size())).collect())
            .collect();
        let scales: Vec<f32> = (0..5).map(|i| 0.1 + 0.07 * i as f32).collect();

        // Reference: the engine's pre-refactor sequential accumulation.
        let mut seq = ParamStore::zeros_like(&meta);
        for (upd, &s) in updates.iter().zip(&scales) {
            for (i, t) in upd.iter().enumerate() {
                for (d, &v) in seq.tensor_mut(i).iter_mut().zip(t) {
                    *d += s * v;
                }
            }
        }

        let terms: Vec<&[Vec<f32>]> = updates.iter().map(|u| u.as_slice()).collect();
        for workers in [1usize, 2, 8] {
            let par = ParamStore::weighted_sum(&meta, &terms, &scales, workers);
            for i in 0..seq.len() {
                let same = seq
                    .tensor(i)
                    .iter()
                    .zip(par.tensor(i))
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "tensor {i} differs at workers={workers}");
            }
        }
    }

    #[test]
    fn weighted_sum_empty_terms_is_zero() {
        let meta = layer_table(ModelKind::LeNet5);
        let z = ParamStore::weighted_sum(&meta, &[], &[], 4);
        assert_eq!(z.l2_norm(), 0.0);
        assert_eq!(z.numel(), meta.total_params());
    }

    #[test]
    fn delta_axpy_roundtrip() {
        let meta = layer_table(ModelKind::LeNet5);
        let a = ParamStore::init(&meta, &Pcg64::seeded(4));
        let mut b = a.clone();
        b.scale(2.0);
        let d = b.delta(&a); // d = a
        let mut rec = a.clone();
        rec.axpy(1.0, &d); // rec = 2a = b
        assert!(rec.delta(&b).l2_norm() < 1e-4);
    }
}
