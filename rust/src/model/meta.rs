//! Architecture metadata: layer names, shapes, and compression geometry.
//!
//! Shapes use JAX conventions: conv kernels are HWIO
//! `[kh, kw, c_in, c_out]`, dense kernels are `[in, out]`. The segment
//! length `l` for the compressor's reshaped gradient matrix `G ∈ R^{l×m}`
//! is the layer's *fan-in* (kh·kw·c_in for conv, `in` for dense) so each
//! column of `G` is one output unit's receptive field — the "natural
//! structural boundary" of paper §III-A.
//!
//! `python/compile/model.py` declares the same tables; `aot.py` writes them
//! into `artifacts/manifest.json` and `rust/tests/artifacts.rs` asserts
//! equality, so the two languages cannot drift silently.

use crate::config::ModelKind;

/// What a tensor does in the network (controls compressibility: the paper
/// compresses only large weight matrices, never biases/norm parameters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerRole {
    /// Convolution kernel (HWIO).
    ConvKernel,
    /// Dense / linear kernel (`[in, out]`).
    DenseKernel,
    /// Bias vector.
    Bias,
    /// Embedding table (`[vocab, dim]`).
    Embedding,
    /// Normalization scale/offset.
    Norm,
}

/// One trainable tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerMeta {
    /// Unique name, e.g. `"stage2.block0.conv1.kernel"`.
    pub name: String,
    /// Tensor shape (JAX conventions, see module docs).
    pub shape: Vec<usize>,
    /// Role (drives compressibility and `l`).
    pub role: LayerRole,
}

impl LayerMeta {
    /// Total element count.
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the paper's compressors may touch this tensor.
    pub fn compressible(&self) -> bool {
        matches!(self.role, LayerRole::ConvKernel | LayerRole::DenseKernel)
    }

    /// Segment length `l` (rows of the reshaped gradient matrix): fan-in.
    pub fn segment_len(&self) -> usize {
        match self.role {
            LayerRole::ConvKernel => self.shape[0] * self.shape[1] * self.shape[2],
            LayerRole::DenseKernel | LayerRole::Embedding => self.shape[0],
            _ => self.size(),
        }
    }

    /// Columns `m = n / l` of the reshaped gradient matrix.
    pub fn segment_cols(&self) -> usize {
        self.size() / self.segment_len()
    }
}

/// A full architecture: ordered tensor list.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    /// Stable name (matches python and artifact paths).
    pub name: &'static str,
    /// Tensors in parameter-list order (the order artifacts expect).
    pub layers: Vec<LayerMeta>,
    /// Input feature shape `[h, w, c]` for vision models, `[seq]` for LM.
    pub input_shape: Vec<usize>,
    /// Number of classes (vision) / vocab size (LM).
    pub num_classes: usize,
}

impl ModelMeta {
    /// Total parameter count.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.size()).sum()
    }

    /// Index of a layer by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.layers.iter().position(|l| l.name == name)
    }

    /// Layers selected for compression, largest first, until `coverage`
    /// of *compressible* parameters is covered (paper §V-B compresses the
    /// parameter-dominant layers: 92–99% of all weights).
    pub fn compression_set(&self, coverage: f64) -> Vec<usize> {
        let mut idx: Vec<usize> =
            (0..self.layers.len()).filter(|&i| self.layers[i].compressible()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(self.layers[i].size()));
        let total: usize = idx.iter().map(|&i| self.layers[i].size()).sum();
        let mut covered = 0usize;
        let mut out = Vec::new();
        for i in idx {
            if (covered as f64) >= coverage * total as f64 {
                break;
            }
            covered += self.layers[i].size();
            out.push(i);
        }
        out.sort_unstable(); // parameter order
        out
    }
}

fn conv(name: &str, kh: usize, kw: usize, cin: usize, cout: usize) -> Vec<LayerMeta> {
    vec![
        LayerMeta {
            name: format!("{name}.kernel"),
            shape: vec![kh, kw, cin, cout],
            role: LayerRole::ConvKernel,
        },
        LayerMeta { name: format!("{name}.bias"), shape: vec![cout], role: LayerRole::Bias },
    ]
}

fn dense(name: &str, d_in: usize, d_out: usize) -> Vec<LayerMeta> {
    vec![
        LayerMeta {
            name: format!("{name}.kernel"),
            shape: vec![d_in, d_out],
            role: LayerRole::DenseKernel,
        },
        LayerMeta { name: format!("{name}.bias"), shape: vec![d_out], role: LayerRole::Bias },
    ]
}

/// Build the canonical layer table for a model.
///
/// Any change here must be mirrored in `python/compile/model.py` (checked by
/// the artifact-manifest integration test).
pub fn layer_table(model: ModelKind) -> ModelMeta {
    match model {
        ModelKind::LeNet5 => {
            let mut layers = Vec::new();
            layers.extend(conv("conv1", 5, 5, 1, 6));
            layers.extend(conv("conv2", 5, 5, 6, 16));
            layers.extend(dense("fc1", 256, 120));
            layers.extend(dense("fc2", 120, 84));
            layers.extend(dense("classifier", 84, 10));
            ModelMeta { name: "lenet5", layers, input_shape: vec![28, 28, 1], num_classes: 10 }
        }
        ModelKind::ResNetLite => {
            let mut layers = Vec::new();
            layers.extend(conv("conv_in", 3, 3, 3, 32));
            for b in 0..2 {
                layers.extend(conv(&format!("stage1.block{b}.conv1"), 3, 3, 32, 32));
                layers.extend(conv(&format!("stage1.block{b}.conv2"), 3, 3, 32, 32));
            }
            layers.extend(conv("down1", 3, 3, 32, 64));
            for b in 0..2 {
                layers.extend(conv(&format!("stage2.block{b}.conv1"), 3, 3, 64, 64));
                layers.extend(conv(&format!("stage2.block{b}.conv2"), 3, 3, 64, 64));
            }
            layers.extend(conv("down2", 3, 3, 64, 128));
            for b in 0..2 {
                layers.extend(conv(&format!("stage3.block{b}.conv1"), 3, 3, 128, 128));
                layers.extend(conv(&format!("stage3.block{b}.conv2"), 3, 3, 128, 128));
            }
            layers.extend(dense("classifier", 128, 10));
            ModelMeta {
                name: "resnetlite",
                layers,
                input_shape: vec![32, 32, 3],
                num_classes: 10,
            }
        }
        ModelKind::AlexNetLite => {
            let mut layers = Vec::new();
            layers.extend(conv("conv1", 3, 3, 3, 32));
            layers.extend(conv("conv2", 3, 3, 32, 64));
            layers.extend(conv("conv3", 3, 3, 64, 128));
            layers.extend(conv("conv4", 3, 3, 128, 128));
            layers.extend(conv("conv5", 3, 3, 128, 128));
            layers.extend(dense("fc1", 2048, 512));
            layers.extend(dense("fc2", 512, 256));
            layers.extend(dense("classifier", 256, 100));
            ModelMeta {
                name: "alexnetlite",
                layers,
                input_shape: vec![32, 32, 3],
                num_classes: 100,
            }
        }
        ModelKind::TinyTransformer => {
            // Decoder-only LM: vocab 256 (bytes), d=128, 4 layers, 4 heads,
            // ff 512, seq 64. Matches python/compile/model.py.
            let (vocab, d, nlayers, dff, seq) = (256, 128, 4, 512, 64);
            let mut layers = Vec::new();
            layers.push(LayerMeta {
                name: "embed.table".into(),
                shape: vec![vocab, d],
                role: LayerRole::Embedding,
            });
            layers.push(LayerMeta {
                name: "pos.table".into(),
                shape: vec![seq, d],
                role: LayerRole::Embedding,
            });
            for i in 0..nlayers {
                for nm in ["wq", "wk", "wv", "wo"] {
                    layers.extend(dense(&format!("layer{i}.attn.{nm}"), d, d));
                }
                layers.push(LayerMeta {
                    name: format!("layer{i}.ln1.scale"),
                    shape: vec![d],
                    role: LayerRole::Norm,
                });
                layers.push(LayerMeta {
                    name: format!("layer{i}.ln1.bias"),
                    shape: vec![d],
                    role: LayerRole::Norm,
                });
                layers.extend(dense(&format!("layer{i}.ff.w1"), d, dff));
                layers.extend(dense(&format!("layer{i}.ff.w2"), dff, d));
                layers.push(LayerMeta {
                    name: format!("layer{i}.ln2.scale"),
                    shape: vec![d],
                    role: LayerRole::Norm,
                });
                layers.push(LayerMeta {
                    name: format!("layer{i}.ln2.bias"),
                    shape: vec![d],
                    role: LayerRole::Norm,
                });
            }
            layers.push(LayerMeta {
                name: "ln_f.scale".into(),
                shape: vec![d],
                role: LayerRole::Norm,
            });
            layers.push(LayerMeta {
                name: "ln_f.bias".into(),
                shape: vec![d],
                role: LayerRole::Norm,
            });
            layers.extend(dense("lm_head", d, vocab));
            ModelMeta {
                name: "tinytransformer",
                layers,
                input_shape: vec![seq],
                num_classes: vocab,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_sizes() {
        let m = layer_table(ModelKind::LeNet5);
        // conv1 150+6, conv2 2400+16, fc1 30720+120, fc2 10080+84, cls 840+10
        assert_eq!(m.total_params(), 150 + 6 + 2400 + 16 + 30720 + 120 + 10080 + 84 + 840 + 10);
    }

    #[test]
    fn names_unique() {
        for kind in [
            ModelKind::LeNet5,
            ModelKind::ResNetLite,
            ModelKind::AlexNetLite,
            ModelKind::TinyTransformer,
        ] {
            let m = layer_table(kind);
            let mut names: Vec<&str> = m.layers.iter().map(|l| l.name.as_str()).collect();
            let n = names.len();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "{kind:?} has duplicate layer names");
        }
    }

    #[test]
    fn segment_geometry_divides_exactly() {
        for kind in [ModelKind::LeNet5, ModelKind::ResNetLite, ModelKind::AlexNetLite] {
            let m = layer_table(kind);
            for l in m.layers.iter().filter(|l| l.compressible()) {
                assert_eq!(
                    l.segment_len() * l.segment_cols(),
                    l.size(),
                    "{}: l*m != n",
                    l.name
                );
            }
        }
    }

    #[test]
    fn resnetlite_deep_layers_dominate() {
        // The paper's premise (Figs. 1-2): a small subset of deep layers
        // holds most parameters. stage3 convs must be ≥ 60% of the model.
        let m = layer_table(ModelKind::ResNetLite);
        let total = m.total_params();
        let stage3: usize = m
            .layers
            .iter()
            .filter(|l| l.name.starts_with("stage3"))
            .map(|l| l.size())
            .sum();
        assert!(
            stage3 as f64 > 0.6 * total as f64,
            "stage3 {stage3} of {total}"
        );
    }

    #[test]
    fn compression_set_covers_target() {
        for kind in [ModelKind::LeNet5, ModelKind::ResNetLite, ModelKind::AlexNetLite] {
            let m = layer_table(kind);
            let set = m.compression_set(0.9);
            let compressible_total: usize =
                m.layers.iter().filter(|l| l.compressible()).map(|l| l.size()).sum();
            let covered: usize = set.iter().map(|&i| m.layers[i].size()).sum();
            assert!(
                covered as f64 >= 0.9 * compressible_total as f64,
                "{kind:?}: covered {covered} of {compressible_total}"
            );
            // Selection must be sorted and compressible.
            assert!(set.windows(2).all(|w| w[0] < w[1]));
            assert!(set.iter().all(|&i| m.layers[i].compressible()));
        }
    }

    #[test]
    fn fan_in_is_segment_len() {
        let m = layer_table(ModelKind::ResNetLite);
        let i = m.index_of("stage3.block0.conv1.kernel").unwrap();
        assert_eq!(m.layers[i].segment_len(), 3 * 3 * 128); // = 1152, the
        // same l the paper uses for ResNet18 layer3 convs (§V-B).
        assert_eq!(m.layers[i].segment_cols(), 128);
    }

    #[test]
    fn alexnet_fc1_dominates() {
        let m = layer_table(ModelKind::AlexNetLite);
        let i = m.index_of("fc1.kernel").unwrap();
        assert!(m.layers[i].size() as f64 > 0.5 * m.total_params() as f64);
    }
}
