//! Gradient preprocessing (paper §III-A(a)): WHDC flatten + segmentation.
//!
//! A layer gradient arrives as a flat tensor in the layer's natural memory
//! order. For compression it is reshaped to `G ∈ R^{l×m}` where **each
//! column** `G[:, j]` is the `j`-th consecutive length-`l` segment of the
//! flat vector (paper Eq. in §III-A: `G[:,j] = [g_{(j-1)l+1}, ..., g_{jl}]ᵀ`).
//!
//! With HWIO conv kernels and `l = kh·kw·c_in` (fan-in), a column is exactly
//! one output channel's receptive-field weights — the WHDC ordering of
//! Fig. 3 — provided the flat vector is laid out fan-in-major. Tensors in
//! this crate and in the JAX models are stored O-outermost (row-major HWIO
//! flattens to (h,w,i) varying fastest within an output channel only if we
//! transpose), so [`segment_matrix`] does the bookkeeping: it treats the
//! flat input as `[m, l]` row-major (m output units × l fan-in weights) and
//! produces the `l×m` matrix by transposition.

use crate::linalg::Mat;

/// Segment a flat gradient (length `l·m`, laid out `[m, l]` row-major:
/// output-unit-major, fan-in contiguous) into the paper's `G ∈ R^{l×m}`.
pub fn segment_matrix(flat: &[f32], l: usize, m: usize) -> Mat {
    assert_eq!(flat.len(), l * m, "segment_matrix: {} != {l}*{m}", flat.len());
    let mut g = Mat::zeros(l, m);
    for j in 0..m {
        let seg = &flat[j * l..(j + 1) * l];
        for i in 0..l {
            g[(i, j)] = seg[i];
        }
    }
    g
}

/// Inverse of [`segment_matrix`]: back to the flat `[m, l]` layout.
pub fn unsegment_matrix(g: &Mat) -> Vec<f32> {
    let (l, m) = (g.rows(), g.cols());
    let mut flat = vec![0.0f32; l * m];
    for j in 0..m {
        for i in 0..l {
            flat[j * l + i] = g[(i, j)];
        }
    }
    flat
}

/// Convert an HWIO-ordered conv kernel tensor (`[kh, kw, cin, cout]`,
/// row-major) into the fan-in-contiguous `[cout, fan_in]` flat layout the
/// segmenter expects, i.e. WHDC ordering per output channel.
pub fn hwio_to_fanin_major(t: &[f32], kh: usize, kw: usize, cin: usize, cout: usize) -> Vec<f32> {
    assert_eq!(t.len(), kh * kw * cin * cout);
    let fan_in = kh * kw * cin;
    let mut out = vec![0.0f32; t.len()];
    for s in 0..fan_in {
        // s indexes (h, w, i) row-major
        for o in 0..cout {
            out[o * fan_in + s] = t[s * cout + o];
        }
    }
    out
}

/// Inverse of [`hwio_to_fanin_major`].
pub fn fanin_major_to_hwio(t: &[f32], kh: usize, kw: usize, cin: usize, cout: usize) -> Vec<f32> {
    assert_eq!(t.len(), kh * kw * cin * cout);
    let fan_in = kh * kw * cin;
    let mut out = vec![0.0f32; t.len()];
    for s in 0..fan_in {
        for o in 0..cout {
            out[s * cout + o] = t[o * fan_in + s];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn segment_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let (l, m) = (12, 7);
        let flat = rng.normal_vec(l * m);
        let g = segment_matrix(&flat, l, m);
        assert_eq!(unsegment_matrix(&g), flat);
    }

    #[test]
    fn columns_are_consecutive_segments() {
        let flat: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let g = segment_matrix(&flat, 4, 3);
        assert_eq!(g.col(0), vec![0., 1., 2., 3.]);
        assert_eq!(g.col(1), vec![4., 5., 6., 7.]);
        assert_eq!(g.col(2), vec![8., 9., 10., 11.]);
    }

    #[test]
    fn hwio_roundtrip() {
        let mut rng = Pcg64::seeded(2);
        let (kh, kw, cin, cout) = (3, 3, 4, 5);
        let t = rng.normal_vec(kh * kw * cin * cout);
        let f = hwio_to_fanin_major(&t, kh, kw, cin, cout);
        assert_eq!(fanin_major_to_hwio(&f, kh, kw, cin, cout), t);
    }

    #[test]
    fn hwio_groups_one_output_channel() {
        // In HWIO layout, output-channel o's weights are strided; after the
        // transform they must be contiguous.
        let (kh, kw, cin, cout) = (2, 1, 2, 3);
        let fan_in = kh * kw * cin; // 4
        let t: Vec<f32> = (0..fan_in * cout).map(|x| x as f32).collect();
        // t[s*cout + o] = s*3 + o
        let f = hwio_to_fanin_major(&t, kh, kw, cin, cout);
        // channel 1 slice must be [1, 4, 7, 10]
        assert_eq!(&f[fan_in..2 * fan_in], &[1.0, 4.0, 7.0, 10.0]);
    }
}
