//! The transport boundary: every byte between coordinator and clients
//! crosses a [`Transport`] as a real encoded frame.
//!
//! The round engine no longer hands in-memory structs from "client" to
//! "server": the coordinator encodes the model broadcast, `broadcast`s it
//! per participant, lanes compress-and-encode their updates, and the
//! coordinator `upload`s and drains those frames before the server-side
//! decode. The communication ledger is charged from the drained buffers'
//! lengths — whatever crossed the transport *is* the accounting.
//!
//! [`Loopback`] is the in-memory implementation the simulator uses:
//! deterministic FIFO queues, no loss, no reordering. A distributed or
//! async backend (sockets, RDMA, a message bus) implements the same four
//! methods and plugs into the engine unchanged.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

/// Byte-level message fabric between the coordinator and its clients.
///
/// Ordering contract: frames are delivered FIFO per direction, and a
/// `drain_*` call yields everything sent since the previous drain, in send
/// order. The engine relies on this to keep accounting in participant
/// order (and therefore bit-deterministic).
///
/// Broadcast frames are `Arc<[u8]>`: a round fans one identical model
/// snapshot out to every participant, so the fabric shares a single
/// allocation instead of materializing `num_clients` dense-model copies —
/// the round's would-be memory high-water mark at production client
/// counts. Uploads are distinct per client and stay owned `Vec<u8>`s.
pub trait Transport: Send {
    /// Queue the server→client broadcast frame for `to`.
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> Result<()>;

    /// Take every delivered broadcast frame, in send order, as
    /// `(client_id, frame)`.
    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)>;

    /// Queue a client→server frame from `from`.
    fn upload(&mut self, from: usize, frame: Vec<u8>) -> Result<()>;

    /// Take every delivered upload, in send order, as `(client_id, frame)`.
    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)>;
}

/// In-memory loopback transport: perfect FIFO delivery within the process.
#[derive(Debug, Default)]
pub struct Loopback {
    downlink: VecDeque<(usize, Arc<[u8]>)>,
    uplink: VecDeque<(usize, Vec<u8>)>,
}

impl Loopback {
    /// Fresh, empty fabric.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Transport for Loopback {
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> Result<()> {
        self.downlink.push_back((to, Arc::clone(frame)));
        Ok(())
    }

    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)> {
        self.downlink.drain(..).collect()
    }

    fn upload(&mut self, from: usize, frame: Vec<u8>) -> Result<()> {
        self.uplink.push_back((from, frame));
        Ok(())
    }

    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)> {
        self.uplink.drain(..).collect()
    }
}

// The coordinator boxes its transport and the box rides inside `Simulation`,
// which tests move across threads; keep the object-safety + Send contract
// checked at compile time.
const _: () = {
    const fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn Transport>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_direction_and_drain_empties() {
        let mut t = Loopback::new();
        let a: Arc<[u8]> = vec![1u8, 2].into();
        let b: Arc<[u8]> = vec![3u8].into();
        t.broadcast(3, &a).unwrap();
        t.broadcast(1, &b).unwrap();
        t.upload(1, vec![9, 9, 9]).unwrap();
        let rx = t.drain_broadcasts();
        assert_eq!(rx.len(), 2);
        assert_eq!((rx[0].0, &rx[0].1[..]), (3, &[1u8, 2][..]));
        assert_eq!((rx[1].0, &rx[1].1[..]), (1, &[3u8][..]));
        assert!(t.drain_broadcasts().is_empty());
        assert_eq!(t.drain_uploads(), vec![(1, vec![9, 9, 9])]);
        assert!(t.drain_uploads().is_empty());
    }

    #[test]
    fn broadcast_shares_one_allocation() {
        let mut t = Loopback::new();
        let frame: Arc<[u8]> = vec![7u8; 16].into();
        t.broadcast(0, &frame).unwrap();
        t.broadcast(1, &frame).unwrap();
        let rx = t.drain_broadcasts();
        assert_eq!(rx.len(), 2);
        // Same bytes, same allocation — no per-client dense-model copies.
        assert!(rx.iter().all(|(_, f)| f[..] == frame[..]));
        assert!(rx.iter().all(|(_, f)| Arc::ptr_eq(f, &frame)));
    }
}
