//! The transport boundary: every byte between coordinator and clients
//! crosses a [`Transport`] as a real encoded frame.
//!
//! The round engine no longer hands in-memory structs from "client" to
//! "server": the coordinator encodes the model broadcast, `broadcast`s it
//! per participant, lanes compress-and-encode their updates, and the
//! coordinator `upload`s and drains those frames before the server-side
//! decode. The communication ledger is charged from the drained buffers'
//! lengths — whatever crossed the transport *is* the accounting.
//!
//! [`Loopback`] is the in-memory implementation the simulator uses:
//! deterministic FIFO queues, no loss, no reordering. A distributed or
//! async backend (sockets, RDMA, a message bus) implements the same four
//! methods and plugs into the engine unchanged.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

/// Byte-level message fabric between the coordinator and its clients.
///
/// Ordering contract: frames are delivered FIFO per direction, and a
/// `drain_*` call yields everything sent since the previous drain, in send
/// order. The engine relies on this to keep accounting in participant
/// order (and therefore bit-deterministic).
///
/// Broadcast frames are `Arc<[u8]>`: a round fans one identical model
/// snapshot out to every participant, so the fabric shares a single
/// allocation instead of materializing `num_clients` dense-model copies —
/// the round's would-be memory high-water mark at production client
/// counts. Uploads are distinct per client and stay owned `Vec<u8>`s.
pub trait Transport: Send {
    /// Queue the server→client broadcast frame for `to`.
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> Result<()>;

    /// Take every delivered broadcast frame, in send order, as
    /// `(client_id, frame)`.
    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)>;

    /// Queue a client→server frame from `from`.
    fn upload(&mut self, from: usize, frame: Vec<u8>) -> Result<()>;

    /// Take every delivered upload, in send order, as `(client_id, frame)`.
    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)>;
}

/// In-memory loopback transport: perfect FIFO delivery within the process.
#[derive(Debug, Default)]
pub struct Loopback {
    downlink: VecDeque<(usize, Arc<[u8]>)>,
    uplink: VecDeque<(usize, Vec<u8>)>,
}

impl Loopback {
    /// Fresh, empty fabric.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Transport for Loopback {
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> Result<()> {
        self.downlink.push_back((to, Arc::clone(frame)));
        Ok(())
    }

    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)> {
        self.downlink.drain(..).collect()
    }

    fn upload(&mut self, from: usize, frame: Vec<u8>) -> Result<()> {
        self.uplink.push_back((from, frame));
        Ok(())
    }

    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)> {
        self.uplink.drain(..).collect()
    }
}

/// Frame/byte counters fed by [`Instrumented`], read by the telemetry
/// plane. Atomic adds are commutative, so the counts are independent of
/// send interleaving.
#[derive(Debug, Default)]
pub struct TransportCounters {
    broadcast_frames: AtomicU64,
    broadcast_bytes: AtomicU64,
    upload_frames: AtomicU64,
    upload_bytes: AtomicU64,
}

impl TransportCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one broadcast frame of `bytes`.
    pub fn add_broadcast(&self, bytes: u64) {
        self.broadcast_frames.fetch_add(1, Ordering::Relaxed);
        self.broadcast_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record one upload frame of `bytes`.
    pub fn add_upload(&self, bytes: u64) {
        self.upload_frames.fetch_add(1, Ordering::Relaxed);
        self.upload_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// `[broadcast_frames, broadcast_bytes, upload_frames, upload_bytes]`.
    pub fn snapshot(&self) -> [u64; 4] {
        [
            self.broadcast_frames.load(Ordering::Relaxed),
            self.broadcast_bytes.load(Ordering::Relaxed),
            self.upload_frames.load(Ordering::Relaxed),
            self.upload_bytes.load(Ordering::Relaxed),
        ]
    }
}

/// Counting wrapper around any [`Transport`]. Installed by
/// `Simulation::enable_telemetry`; forwards every call unchanged (same
/// FIFO order, same shared broadcast allocation) and bumps
/// [`TransportCounters`] on the send side.
pub struct Instrumented {
    inner: Box<dyn Transport>,
    counters: Arc<TransportCounters>,
}

impl Instrumented {
    /// Wrap `inner`, feeding `counters`.
    pub fn new(inner: Box<dyn Transport>, counters: Arc<TransportCounters>) -> Self {
        Instrumented { inner, counters }
    }
}

impl Transport for Instrumented {
    fn broadcast(&mut self, to: usize, frame: &Arc<[u8]>) -> Result<()> {
        self.counters.add_broadcast(frame.len() as u64);
        self.inner.broadcast(to, frame)
    }

    fn drain_broadcasts(&mut self) -> Vec<(usize, Arc<[u8]>)> {
        self.inner.drain_broadcasts()
    }

    fn upload(&mut self, from: usize, frame: Vec<u8>) -> Result<()> {
        self.counters.add_upload(frame.len() as u64);
        self.inner.upload(from, frame)
    }

    fn drain_uploads(&mut self) -> Vec<(usize, Vec<u8>)> {
        self.inner.drain_uploads()
    }
}

// The coordinator boxes its transport and the box rides inside `Simulation`,
// which tests move across threads; keep the object-safety + Send contract
// checked at compile time.
const _: () = {
    const fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn Transport>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_direction_and_drain_empties() {
        let mut t = Loopback::new();
        let a: Arc<[u8]> = vec![1u8, 2].into();
        let b: Arc<[u8]> = vec![3u8].into();
        t.broadcast(3, &a).unwrap();
        t.broadcast(1, &b).unwrap();
        t.upload(1, vec![9, 9, 9]).unwrap();
        let rx = t.drain_broadcasts();
        assert_eq!(rx.len(), 2);
        assert_eq!((rx[0].0, &rx[0].1[..]), (3, &[1u8, 2][..]));
        assert_eq!((rx[1].0, &rx[1].1[..]), (1, &[3u8][..]));
        assert!(t.drain_broadcasts().is_empty());
        assert_eq!(t.drain_uploads(), vec![(1, vec![9, 9, 9])]);
        assert!(t.drain_uploads().is_empty());
    }

    #[test]
    fn instrumented_counts_without_changing_delivery() {
        let counters = Arc::new(TransportCounters::new());
        let mut t = Instrumented::new(Box::new(Loopback::new()), Arc::clone(&counters));
        let frame: Arc<[u8]> = vec![0u8; 10].into();
        t.broadcast(0, &frame).unwrap();
        t.broadcast(1, &frame).unwrap();
        t.upload(1, vec![1, 2, 3]).unwrap();
        assert_eq!(counters.snapshot(), [2, 20, 1, 3]);
        let rx = t.drain_broadcasts();
        assert_eq!(rx.len(), 2);
        assert!(rx.iter().all(|(_, f)| Arc::ptr_eq(f, &frame)));
        assert_eq!(t.drain_uploads(), vec![(1, vec![1, 2, 3])]);
        // Drains don't double-count.
        assert_eq!(counters.snapshot(), [2, 20, 1, 3]);
    }

    #[test]
    fn broadcast_shares_one_allocation() {
        let mut t = Loopback::new();
        let frame: Arc<[u8]> = vec![7u8; 16].into();
        t.broadcast(0, &frame).unwrap();
        t.broadcast(1, &frame).unwrap();
        let rx = t.drain_broadcasts();
        assert_eq!(rx.len(), 2);
        // Same bytes, same allocation — no per-client dense-model copies.
        assert!(rx.iter().all(|(_, f)| f[..] == frame[..]));
        assert!(rx.iter().all(|(_, f)| Arc::ptr_eq(f, &frame)));
    }
}
