//! Per-client link profiles, heterogeneous link sampling, and the
//! client-dropout model.
//!
//! Real federated deployments do not share one perfect pipe: edge clients
//! sit behind links whose bandwidth and latency vary by orders of
//! magnitude, some disappear mid-round, and a synchronous server cannot
//! wait forever for the slowest (Ozfatura et al.'s partial-participation
//! setting; Edin et al.'s practical-limitations study). [`NetConfig`] is
//! the experiment-facing knob set; it samples one [`LinkProfile`] per
//! client — deterministically from the run seed via
//! [`Pcg64`](crate::util::rng::Pcg64) — and owns the dropout rate and
//! straggler deadline the coordinator enforces.

use crate::util::rng::Pcg64;

/// One client's link: asymmetric bandwidth plus per-message latency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkProfile {
    /// Client→server bandwidth in bytes/sec.
    pub uplink_bps: f64,
    /// Server→client bandwidth in bytes/sec.
    pub downlink_bps: f64,
    /// Per-message latency in seconds.
    pub latency_s: f64,
}

impl LinkProfile {
    /// The bandwidth-constrained edge setting the paper's intro targets:
    /// 10 Mbit/s up, 50 Mbit/s down, 30 ms latency.
    pub fn edge_default() -> Self {
        LinkProfile { uplink_bps: 10e6 / 8.0, downlink_bps: 50e6 / 8.0, latency_s: 0.03 }
    }

    /// Seconds to move `bytes` up the constrained link.
    pub fn uplink_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.uplink_bps
    }

    /// Seconds to move `bytes` down.
    pub fn downlink_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.downlink_bps
    }

    /// Seconds for one synchronous round trip on this link: receive the
    /// broadcast, then push the update back up.
    pub fn round_trip_time(&self, down_bytes: u64, up_bytes: u64) -> f64 {
        self.downlink_time(down_bytes) + self.uplink_time(up_bytes)
    }
}

/// Experiment-facing network knobs (`ExperimentConfig::net`, the CLI's
/// `--up-mbps`/`--dropout`/… flags, and the `"net"` JSON object).
///
/// The default — homogeneous edge links, no dropout, no deadline — keeps
/// the simulation byte- and bit-identical to the pre-transport engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    /// Mean client→server bandwidth, Mbit/s.
    pub uplink_mbps: f64,
    /// Mean server→client bandwidth, Mbit/s.
    pub downlink_mbps: f64,
    /// Mean per-message latency, milliseconds.
    pub latency_ms: f64,
    /// Heterogeneity: per-client bandwidth/latency are scaled by
    /// `exp(het_spread · N(0,1))` (log-normal). `0` = identical links.
    pub het_spread: f64,
    /// Per-round, per-client probability of dropping out before the round
    /// starts (no broadcast received, no upload sent). `0` = never.
    pub dropout: f64,
    /// Straggler deadline in seconds: a client whose broadcast+upload
    /// transfer exceeds this arrives too late and is excluded from the
    /// aggregate (its bytes still crossed the wire and are still charged).
    /// `0` = the server waits for everyone.
    pub deadline_s: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            uplink_mbps: 10.0,
            downlink_mbps: 50.0,
            latency_ms: 30.0,
            het_spread: 0.0,
            dropout: 0.0,
            deadline_s: 0.0,
        }
    }
}

impl NetConfig {
    /// Range-check the knobs; returns a description of the first problem.
    /// Called by `Simulation::build` so bad CLI/JSON values surface as
    /// config errors, not panics.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(format!("net.dropout = {} must be in [0, 1)", self.dropout));
        }
        for (name, v) in [("uplink_mbps", self.uplink_mbps), ("downlink_mbps", self.downlink_mbps)]
        {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("net.{name} = {v} must be a positive bandwidth"));
            }
        }
        for (name, v) in [
            ("latency_ms", self.latency_ms),
            ("het_spread", self.het_spread),
            ("deadline_s", self.deadline_s),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("net.{name} = {v} must be finite and non-negative"));
            }
        }
        Ok(())
    }

    /// The mean link these knobs describe.
    pub fn base_profile(&self) -> LinkProfile {
        LinkProfile {
            uplink_bps: self.uplink_mbps * 1e6 / 8.0,
            downlink_bps: self.downlink_mbps * 1e6 / 8.0,
            latency_s: self.latency_ms / 1e3,
        }
    }

    /// The straggler deadline, `None` when disabled.
    pub fn deadline(&self) -> Option<f64> {
        (self.deadline_s > 0.0).then_some(self.deadline_s)
    }

    /// Sample one link per client. Deterministic in `(self, n, seed)`; with
    /// `het_spread == 0` every client gets exactly [`Self::base_profile`]
    /// and no RNG is consumed.
    pub fn sample_links(&self, n: usize, seed: u64) -> Vec<LinkProfile> {
        let base = self.base_profile();
        if self.het_spread == 0.0 {
            return vec![base; n];
        }
        let root = Pcg64::new(seed, 0x4E57_11);
        (0..n)
            .map(|cid| {
                let mut r = root.fork(cid as u64);
                let bw = (self.het_spread * r.normal()).exp();
                let lat = (self.het_spread * r.normal()).exp();
                LinkProfile {
                    uplink_bps: base.uplink_bps * bw,
                    downlink_bps: base.downlink_bps * bw,
                    latency_s: base.latency_s * lat,
                }
            })
            .collect()
    }
}

/// Per-round client-dropout decisions.
///
/// `survives(round, cid)` is a pure function of `(seed, round, cid)` — no
/// shared RNG stream to advance — so the surviving-client set is identical
/// at every worker count and independent of evaluation order, which is
/// what keeps dropout runs bit-reproducible.
#[derive(Clone, Copy, Debug)]
pub struct DropoutModel {
    rate: f64,
    seed: u64,
}

impl DropoutModel {
    /// `rate` ∈ [0, 1); `0` disables dropout entirely. User-facing rates
    /// are range-checked earlier by [`NetConfig::validate`]; this assert
    /// only guards internal callers.
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&rate), "dropout rate {rate} must be in [0, 1)");
        DropoutModel { rate, seed }
    }

    /// Does client `cid` stay up for `round`?
    pub fn survives(&self, round: usize, cid: usize) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let mix = self.seed ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg64::new(mix, 0xD209_0000 ^ cid as u64).f64() >= self.rate
    }

    /// Filter a participant set down to the surviving clients, preserving
    /// order.
    pub fn filter(&self, round: usize, participants: &[usize]) -> Vec<usize> {
        participants.iter().copied().filter(|&cid| self.survives(round, cid)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_legacy_edge_profile() {
        let base = NetConfig::default().base_profile();
        assert_eq!(base, LinkProfile::edge_default());
        assert_eq!(NetConfig::default().deadline(), None);
    }

    #[test]
    fn validate_rejects_out_of_range_knobs() {
        assert!(NetConfig::default().validate().is_ok());
        for bad in [
            NetConfig { dropout: 1.0, ..Default::default() },
            NetConfig { dropout: -0.1, ..Default::default() },
            NetConfig { uplink_mbps: 0.0, ..Default::default() },
            NetConfig { downlink_mbps: -5.0, ..Default::default() },
            NetConfig { latency_ms: f64::NAN, ..Default::default() },
            NetConfig { het_spread: -1.0, ..Default::default() },
            NetConfig { deadline_s: f64::INFINITY, ..Default::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn times_monotone_and_asymmetric() {
        let l = LinkProfile::edge_default();
        assert!(l.uplink_time(1_000_000) > l.uplink_time(1_000));
        assert!(l.uplink_time(1_000_000) > l.downlink_time(1_000_000));
        let rt = l.round_trip_time(1000, 2000);
        assert!((rt - (l.downlink_time(1000) + l.uplink_time(2000))).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_sampling_is_exact_and_rng_free() {
        let cfg = NetConfig::default();
        let links = cfg.sample_links(5, 42);
        assert_eq!(links.len(), 5);
        assert!(links.iter().all(|l| *l == cfg.base_profile()));
    }

    #[test]
    fn heterogeneous_sampling_deterministic_and_spread() {
        let cfg = NetConfig { het_spread: 0.5, ..Default::default() };
        let a = cfg.sample_links(20, 7);
        let b = cfg.sample_links(20, 7);
        assert_eq!(a, b);
        let c = cfg.sample_links(20, 8);
        assert_ne!(a, c);
        // Links must actually differ from each other.
        assert!(a.windows(2).any(|w| w[0].uplink_bps != w[1].uplink_bps));
        assert!(a.iter().all(|l| l.uplink_bps > 0.0 && l.latency_s > 0.0));
    }

    #[test]
    fn dropout_zero_never_drops() {
        let d = DropoutModel::new(0.0, 1);
        assert!((0..100).all(|r| (0..20).all(|c| d.survives(r, c))));
    }

    #[test]
    fn dropout_rate_roughly_respected() {
        let d = DropoutModel::new(0.3, 99);
        let total = 200 * 50;
        let survived: usize =
            (0..200).map(|r| (0..50).filter(|&c| d.survives(r, c)).count()).sum();
        let frac = survived as f64 / total as f64;
        assert!((frac - 0.7).abs() < 0.03, "survival fraction {frac}");
    }

    #[test]
    fn dropout_is_pure_per_round_and_client() {
        let d = DropoutModel::new(0.4, 5);
        // Same query twice → same answer; varies across rounds and clients.
        for r in 0..10 {
            for c in 0..10 {
                assert_eq!(d.survives(r, c), d.survives(r, c));
            }
        }
        let per_round: Vec<Vec<usize>> =
            (0..10).map(|r| d.filter(r, &(0..10).collect::<Vec<_>>())).collect();
        assert!(per_round.windows(2).any(|w| w[0] != w[1]), "dropout never varied");
    }
}
