//! Binary wire codec for compressed-update payloads and model broadcasts.
//!
//! Every frame is `[tag: u32 LE][body_len: u32 LE][body]` — exactly the
//! 8-byte [`FRAME_HEADER`] the accounting has always charged — and every
//! body layout is arranged so the encoded size of a payload equals
//! [`Payload::wire_bytes`] to the byte. That identity is the codec's
//! contract: `wire_bytes` used to be a *claim* about what a serializer
//! would emit; it is now a *checked invariant* over this encoder
//! (`debug_assert`ed on every encode, property-tested in
//! `rust/tests/properties.rs`).
//!
//! Body layouts (all little-endian):
//!
//! | variant     | body                                                        |
//! |-------------|-------------------------------------------------------------|
//! | `Raw`       | `f32 × n`                                                   |
//! | `Sparse`    | `len u32, indices u32 × k, values f32 × k`                  |
//! | `Quantized` | `lo f32, hi f32, bits u8, len u32, packed bytes`            |
//! | `Signs`     | `scale f32, len u32, packed bytes`                          |
//! | `Basis`     | `l u32, k u32, m u32, ℙ u32 × d, 𝕄 f32 × d·l, A f32 × k·m` |
//! | `SvdCoeffs` | `l u32, k u32, m u32, flag u8, A f32 × k·m[, basis f32 × r]`|
//!
//! Counts that are not stored explicitly (`Sparse` pair count, `Basis`
//! replacement count `d`, `SvdCoeffs` refit length) are derived from the
//! frame length and validated, so `decode` rejects truncated or
//! inconsistent frames instead of misreading them.

use anyhow::{anyhow, bail, ensure, Result};

use crate::compress::codec::{Payload, FRAME_HEADER};
use crate::model::meta::ModelMeta;
use crate::model::params::ParamStore;

const TAG_RAW: u32 = 0;
const TAG_SPARSE: u32 = 1;
const TAG_QUANTIZED: u32 = 2;
const TAG_SIGNS: u32 = 3;
const TAG_BASIS: u32 = 4;
const TAG_SVD: u32 = 5;

// ---- encoding --------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    for &v in vs {
        put_f32(buf, v);
    }
}

fn put_u32s(buf: &mut Vec<u8>, vs: &[u32]) {
    for &v in vs {
        put_u32(buf, v);
    }
}

/// Lossless `usize → u32` for on-wire counts (tensor sizes are far below
/// 2^32; a violation is a programming error, not a runtime condition).
fn wire_u32(n: usize, what: &str) -> u32 {
    u32::try_from(n).unwrap_or_else(|_| panic!("{what} = {n} exceeds the u32 wire format"))
}

/// Encode a client's full payload list into one framed byte buffer.
///
/// The result's length equals `Σ p.wire_bytes()` exactly — the invariant
/// the communication ledger relies on.
pub fn encode(payloads: &[Payload]) -> Vec<u8> {
    let total: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
    let mut buf = Vec::with_capacity(total as usize);
    for p in payloads {
        encode_one(&mut buf, p);
    }
    debug_assert_eq!(buf.len() as u64, total, "encoded length != Σ wire_bytes");
    buf
}

fn encode_one(buf: &mut Vec<u8>, p: &Payload) {
    let start = buf.len();
    let tag = match p {
        Payload::Raw(..) => TAG_RAW,
        Payload::Sparse { .. } => TAG_SPARSE,
        Payload::Quantized { .. } => TAG_QUANTIZED,
        Payload::Signs { .. } => TAG_SIGNS,
        Payload::Basis { .. } => TAG_BASIS,
        Payload::SvdCoeffs { .. } => TAG_SVD,
    };
    put_u32(buf, tag);
    let len_pos = buf.len();
    put_u32(buf, 0); // patched below
    debug_assert_eq!((buf.len() - start) as u64, FRAME_HEADER);
    match p {
        Payload::Raw(v) => put_f32s(buf, v),
        Payload::Sparse { indices, values, len } => {
            assert_eq!(indices.len(), values.len(), "sparse index/value mismatch");
            put_u32(buf, wire_u32(*len, "sparse len"));
            put_u32s(buf, indices);
            put_f32s(buf, values);
        }
        Payload::Quantized { lo, hi, bits, packed, len } => {
            put_f32(buf, *lo);
            put_f32(buf, *hi);
            buf.push(*bits);
            put_u32(buf, wire_u32(*len, "quantized len"));
            buf.extend_from_slice(packed);
        }
        Payload::Signs { scale, packed, len } => {
            put_f32(buf, *scale);
            put_u32(buf, wire_u32(*len, "signs len"));
            buf.extend_from_slice(packed);
        }
        Payload::Basis { replace_idx, new_vectors, coeffs, l, k, m } => {
            assert_eq!(new_vectors.len(), replace_idx.len() * l, "basis 𝕄 geometry");
            assert_eq!(coeffs.len(), k * m, "basis A geometry");
            put_u32(buf, wire_u32(*l, "basis l"));
            put_u32(buf, wire_u32(*k, "basis k"));
            put_u32(buf, wire_u32(*m, "basis m"));
            put_u32s(buf, replace_idx);
            put_f32s(buf, new_vectors);
            put_f32s(buf, coeffs);
        }
        Payload::SvdCoeffs { coeffs, refit_basis, l, k, m } => {
            assert_eq!(coeffs.len(), k * m, "svd A geometry");
            put_u32(buf, wire_u32(*l, "svd l"));
            put_u32(buf, wire_u32(*k, "svd k"));
            put_u32(buf, wire_u32(*m, "svd m"));
            buf.push(refit_basis.is_some() as u8);
            put_f32s(buf, coeffs);
            if let Some(basis) = refit_basis {
                put_f32s(buf, basis);
            }
        }
    }
    let body = (buf.len() - len_pos - 4) as u32;
    buf[len_pos..len_pos + 4].copy_from_slice(&body.to_le_bytes());
    debug_assert_eq!((buf.len() - start) as u64, p.wire_bytes());
}

// ---- decoding --------------------------------------------------------------

/// Bounds-checked little-endian cursor over a received frame.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Reader { b, i: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "frame truncated: wanted {n} bytes, {} left", self.remaining());
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.bytes(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let s = self.bytes(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        // Guard n before multiplying: header-supplied counts must never
        // overflow the size arithmetic, only fail cleanly.
        ensure!(n <= self.remaining() / 4, "frame truncated: wanted {n} u32s");
        let s = self.bytes(n * 4)?;
        Ok(s.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        ensure!(n <= self.remaining() / 4, "frame truncated: wanted {n} f32s");
        let s = self.bytes(n * 4)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// Decode a framed byte buffer back into the payload list (inverse of
/// [`encode`], bit-exact). Fails on truncated, trailing, or inconsistent
/// frames.
pub fn decode(bytes: &[u8]) -> Result<Vec<Payload>> {
    let mut r = Reader::new(bytes);
    let mut out = Vec::new();
    while r.remaining() > 0 {
        out.push(decode_one(&mut r)?);
    }
    Ok(out)
}

fn decode_one(r: &mut Reader<'_>) -> Result<Payload> {
    let tag = r.u32()?;
    let body_len = r.u32()? as usize;
    let mut b = Reader::new(r.bytes(body_len)?);
    let payload = match tag {
        TAG_RAW => {
            ensure!(body_len % 4 == 0, "raw body length {body_len} not a multiple of 4");
            Payload::Raw(b.f32s(body_len / 4)?)
        }
        TAG_SPARSE => {
            let len = b.u32()? as usize;
            let rest = b.remaining();
            ensure!(rest % 8 == 0, "sparse body has {rest} pair bytes (not a multiple of 8)");
            let pairs = rest / 8;
            ensure!(pairs <= len, "sparse frame claims {pairs} pairs for a length-{len} tensor");
            let indices = b.u32s(pairs)?;
            let values = b.f32s(pairs)?;
            // Strictly increasing ⇒ in-bounds-checked, sorted, and free of
            // duplicates — the producer contract (top-k emits sorted unique
            // indices) that keeps the scatter-add fold and the dense
            // reconstruction equivalent. Reject hostile frames that break it.
            for (pos, &i) in indices.iter().enumerate() {
                ensure!((i as usize) < len, "sparse index {i} out of bounds (len {len})");
                ensure!(
                    pos == 0 || indices[pos - 1] < i,
                    "sparse indices not strictly increasing at pair {pos}"
                );
            }
            Payload::Sparse { indices, values, len }
        }
        TAG_QUANTIZED => {
            let lo = b.f32()?;
            let hi = b.f32()?;
            let bits = b.u8()?;
            let len = b.u32()? as usize;
            ensure!((1..=16).contains(&bits), "quantized bit width {bits} outside 1..=16");
            let expect = (len * bits as usize).div_ceil(8);
            ensure!(
                b.remaining() == expect,
                "quantized frame holds {} packed bytes, geometry needs {expect}",
                b.remaining()
            );
            let packed = b.bytes(expect)?.to_vec();
            Payload::Quantized { lo, hi, bits, packed, len }
        }
        TAG_SIGNS => {
            let scale = b.f32()?;
            let len = b.u32()? as usize;
            let expect = len.div_ceil(8);
            ensure!(
                b.remaining() == expect,
                "signs frame holds {} packed bytes, geometry needs {expect}",
                b.remaining()
            );
            let packed = b.bytes(expect)?.to_vec();
            Payload::Signs { scale, packed, len }
        }
        TAG_BASIS => {
            let l = b.u32()? as usize;
            let k = b.u32()? as usize;
            let m = b.u32()? as usize;
            // Checked product: k and m come off the wire, so k·m may not
            // fit — reject instead of overflowing in debug builds.
            let km = k
                .checked_mul(m)
                .filter(|&km| km <= b.remaining() / 4)
                .ok_or_else(|| {
                    anyhow!("basis frame too short for the {k}x{m} coefficient block")
                })?;
            // Replacement count d is implicit: the variable region holds
            // d indices + d·l vector entries, 4·d·(1+l) bytes.
            let var = b.remaining() - 4 * km;
            let per = 4 * (l + 1);
            ensure!(var % per == 0, "basis frame geometry: {var} variable bytes, {per} per replacement");
            let d = var / per;
            let replace_idx = b.u32s(d)?;
            for &i in &replace_idx {
                ensure!((i as usize) < k, "basis replacement index {i} out of bounds (k {k})");
            }
            let new_vectors = b.f32s(d * l)?;
            let coeffs = b.f32s(km)?;
            Payload::Basis { replace_idx, new_vectors, coeffs, l, k, m }
        }
        TAG_SVD => {
            let l = b.u32()? as usize;
            let k = b.u32()? as usize;
            let m = b.u32()? as usize;
            let flag = b.u8()?;
            ensure!(flag <= 1, "svd refit flag {flag} is not 0/1");
            let km = k
                .checked_mul(m)
                .ok_or_else(|| anyhow!("svd frame claims an impossible {k}x{m} block"))?;
            let coeffs = b.f32s(km)?;
            let refit_basis = if flag == 1 {
                let rest = b.remaining();
                ensure!(rest % 4 == 0, "svd refit block of {rest} bytes not a multiple of 4");
                Some(b.f32s(rest / 4)?)
            } else {
                None
            };
            Payload::SvdCoeffs { coeffs, refit_basis, l, k, m }
        }
        other => bail!("unknown payload tag {other}"),
    };
    ensure!(b.remaining() == 0, "frame has {} trailing bytes", b.remaining());
    Ok(payload)
}

// ---- model broadcast -------------------------------------------------------

/// Encode the global model for broadcast: the dense f32 tensors in layer
/// order, little-endian, no per-tensor framing (a model snapshot is one
/// logical message). Exactly `4 · numel` bytes — the figure the downlink
/// has always been charged.
pub fn encode_params(params: &ParamStore) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 * params.numel());
    for t in params.iter() {
        put_f32s(&mut buf, t);
    }
    buf
}

/// Decode a broadcast frame back into a [`ParamStore`] (bit-exact inverse
/// of [`encode_params`]); `meta` supplies the tensor geometry.
pub fn decode_params(meta: &ModelMeta, bytes: &[u8]) -> Result<ParamStore> {
    let total: usize = meta.layers.iter().map(|l| l.size()).sum();
    ensure!(
        bytes.len() == 4 * total,
        "broadcast frame is {} bytes, model needs {}",
        bytes.len(),
        4 * total
    );
    let mut r = Reader::new(bytes);
    let tensors: Vec<Vec<f32>> =
        meta.layers.iter().map(|l| r.f32s(l.size())).collect::<Result<_>>()?;
    Ok(ParamStore::from_tensors(meta, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::codec::pack_bits;
    use crate::config::ModelKind;
    use crate::model::meta::layer_table;
    use crate::util::rng::Pcg64;

    fn roundtrip(p: Payload) {
        let buf = encode(std::slice::from_ref(&p));
        assert_eq!(buf.len() as u64, p.wire_bytes(), "{p:?}");
        let back = decode(&buf).unwrap();
        assert_eq!(back, vec![p]);
    }

    #[test]
    fn raw_roundtrip_exact_length() {
        roundtrip(Payload::Raw(vec![1.0, -2.5, 3.25e-7, f32::MIN_POSITIVE]));
        roundtrip(Payload::Raw(Vec::new()));
    }

    #[test]
    fn sparse_roundtrip() {
        roundtrip(Payload::Sparse {
            indices: vec![0, 7, 93],
            values: vec![0.5, -0.25, 19.0],
            len: 100,
        });
    }

    #[test]
    fn quantized_roundtrip_odd_lengths_and_widths() {
        for (bits, len) in [(1u8, 13usize), (3, 17), (8, 64), (12, 5), (16, 9)] {
            let mut rng = Pcg64::seeded(bits as u64 * 100 + len as u64);
            let max = (1u64 << bits) - 1;
            let codes: Vec<u32> = (0..len).map(|_| rng.below(max + 1) as u32).collect();
            roundtrip(Payload::Quantized {
                lo: -1.5,
                hi: 2.25,
                bits,
                packed: pack_bits(&codes, bits),
                len,
            });
        }
    }

    #[test]
    fn signs_roundtrip_non_multiple_of_8() {
        let codes: Vec<u32> = (0..21).map(|i| (i % 2) as u32).collect();
        roundtrip(Payload::Signs { scale: 0.03, packed: pack_bits(&codes, 1), len: 21 });
    }

    #[test]
    fn basis_roundtrip_including_empty_replacement() {
        let (l, k, m) = (16usize, 4usize, 6usize);
        for d in [0usize, 1, 3] {
            let mut rng = Pcg64::seeded(d as u64 + 5);
            roundtrip(Payload::Basis {
                replace_idx: (0..d as u32).collect(),
                new_vectors: rng.normal_vec(d * l),
                coeffs: rng.normal_vec(k * m),
                l,
                k,
                m,
            });
        }
    }

    #[test]
    fn svd_roundtrip_with_and_without_refit() {
        let (l, k, m) = (32usize, 5usize, 7usize);
        let mut rng = Pcg64::seeded(11);
        roundtrip(Payload::SvdCoeffs {
            coeffs: rng.normal_vec(k * m),
            refit_basis: None,
            l,
            k,
            m,
        });
        roundtrip(Payload::SvdCoeffs {
            coeffs: rng.normal_vec(k * m),
            refit_basis: Some(rng.normal_vec(k * l)),
            l,
            k,
            m,
        });
    }

    #[test]
    fn multi_payload_buffer_roundtrips_in_order() {
        let payloads = vec![
            Payload::Raw(vec![1.0, 2.0]),
            Payload::Signs { scale: 1.5, packed: vec![0b101], len: 3 },
            Payload::Raw(vec![-4.0]),
        ];
        let buf = encode(&payloads);
        let total: u64 = payloads.iter().map(|p| p.wire_bytes()).sum();
        assert_eq!(buf.len() as u64, total);
        assert_eq!(decode(&buf).unwrap(), payloads);
    }

    #[test]
    fn decode_rejects_garbage() {
        // Unknown tag.
        let mut buf = Vec::new();
        put_u32(&mut buf, 99);
        put_u32(&mut buf, 0);
        assert!(decode(&buf).is_err());
        // Truncated body.
        let good = encode(&[Payload::Raw(vec![1.0, 2.0, 3.0])]);
        assert!(decode(&good[..good.len() - 2]).is_err());
        // Trailing junk after a valid frame.
        let mut padded = good.clone();
        padded.push(0xFF);
        assert!(decode(&padded).is_err());
        // Inconsistent quantized geometry: claim len 100 with 1 packed byte.
        let mut bad = Vec::new();
        put_u32(&mut bad, TAG_QUANTIZED);
        put_u32(&mut bad, 4 + 4 + 1 + 4 + 1);
        put_f32(&mut bad, 0.0);
        put_f32(&mut bad, 1.0);
        bad.push(8);
        put_u32(&mut bad, 100);
        bad.push(0);
        assert!(decode(&bad).is_err());
        // Duplicate / unsorted sparse indices violate the producer
        // contract the scatter-add fold relies on: reject the frame.
        for indices in [vec![3u32, 3, 7], vec![7u32, 3, 9]] {
            let sparse = encode(&[Payload::Sparse {
                indices,
                values: vec![1.0, 2.0, 3.0],
                len: 10,
            }]);
            assert!(decode(&sparse).is_err(), "non-increasing sparse indices accepted");
        }
        // Hostile dimension headers whose products would overflow the size
        // arithmetic must fail cleanly, not panic.
        for tag in [TAG_BASIS, TAG_SVD] {
            let mut evil = Vec::new();
            put_u32(&mut evil, tag);
            put_u32(&mut evil, 13);
            put_u32(&mut evil, u32::MAX); // l
            put_u32(&mut evil, u32::MAX); // k
            put_u32(&mut evil, u32::MAX); // m
            evil.push(1);
            assert!(decode(&evil).is_err(), "tag {tag}");
        }
    }

    #[test]
    fn params_broadcast_roundtrip_bit_exact() {
        let meta = layer_table(ModelKind::LeNet5);
        let params = ParamStore::init(&meta, &Pcg64::seeded(3));
        let frame = encode_params(&params);
        assert_eq!(frame.len(), 4 * params.numel());
        let back = decode_params(&meta, &frame).unwrap();
        for i in 0..params.len() {
            let same = params
                .tensor(i)
                .iter()
                .zip(back.tensor(i))
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "tensor {i} not bit-exact");
        }
        assert!(decode_params(&meta, &frame[..frame.len() - 4]).is_err());
    }
}
