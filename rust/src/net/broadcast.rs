//! Model-version-keyed broadcast-encode cache, shared by all schedulers.
//!
//! Encoding the dense global model is the broadcast path's only O(model)
//! CPU cost, and between applies the model does not change: sync rounds
//! that fold nothing, semi-sync rounds whose whole cohort missed the
//! deadline, and async dispatch groups between buffer flushes all re-ship
//! the *same* frame. The async scheduler used to keep a private
//! `(version, frame)` memo for exactly this reason; [`BroadcastCache`]
//! lifts it to the net layer so every scheduler encodes each model version
//! at most once.
//!
//! One entry suffices (no map): the model version only moves forward, and
//! a scheduler never re-broadcasts an old version after applying a new
//! one. The coordinator owns the instance and bumps its version counter at
//! each apply — see `Simulation::broadcast_frame`.

use std::sync::Arc;

/// Single-entry `(model version → encoded frame)` memo with hit/miss
/// counters for telemetry.
#[derive(Default)]
pub struct BroadcastCache {
    entry: Option<(u64, Arc<[u8]>)>,
    hits: u64,
    misses: u64,
}

impl BroadcastCache {
    /// An empty cache.
    pub fn new() -> BroadcastCache {
        BroadcastCache::default()
    }

    /// The cached frame for `version`, if the last `put` stored exactly
    /// that version. Counts a hit or a miss.
    pub fn get(&mut self, version: u64) -> Option<Arc<[u8]>> {
        match &self.entry {
            Some((v, frame)) if *v == version => {
                self.hits += 1;
                Some(Arc::clone(frame))
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store the frame encoded for `version`, displacing any older entry.
    pub fn put(&mut self, version: u64, frame: Arc<[u8]>) {
        self.entry = Some((version, frame));
    }

    /// `(hits, misses)` since construction.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_one_version_and_counts() {
        let mut c = BroadcastCache::new();
        assert!(c.get(0).is_none());
        let f: Arc<[u8]> = vec![1u8, 2, 3].into();
        c.put(0, Arc::clone(&f));
        let got = c.get(0).unwrap();
        assert!(Arc::ptr_eq(&got, &f));
        // A new version displaces the old entry.
        assert!(c.get(1).is_none());
        c.put(1, vec![4u8].into());
        assert!(c.get(0).is_none());
        assert_eq!(c.get(1).unwrap().as_ref(), &[4u8]);
        assert_eq!(c.counters(), (2, 3));
    }
}
