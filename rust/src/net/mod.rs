//! The network boundary: wire-format codec, per-client link simulation,
//! and the transport layer the coordinator routes every byte through.
//!
//! The paper's headline claim is uplink bytes saved on bandwidth-starved
//! edge links (§I, Table III). Before this subsystem the repository only
//! *priced* serialization — [`Payload::wire_bytes`](crate::compress::Payload::wire_bytes)
//! computed what a binary encoder would emit, but no bytes ever existed and
//! every client shared one perfect link. This module makes the boundary
//! real:
//!
//! * [`wire`] — the binary codec. `encode` turns a client's payload list
//!   into one framed byte buffer; `decode` reconstructs it bit-exactly.
//!   The codec is the *definition* of `wire_bytes`: for every payload,
//!   `encode([p]).len() == p.wire_bytes()` (property-tested), so the
//!   accounting the paper's tables are built from is charged off actual
//!   buffer lengths, not estimates.
//! * [`link`] — per-client [`LinkProfile`]s (bandwidth + latency), the
//!   [`NetConfig`] experiment knobs that sample them (heterogeneous spread,
//!   dropout rate, straggler deadline; deterministic per seed via
//!   [`crate::util::rng::Pcg64`]), and the [`DropoutModel`].
//! * [`transport`] — the [`Transport`] trait every broadcast/upload crosses
//!   as real byte buffers, with the in-memory [`Loopback`] implementation
//!   the simulator uses. A future distributed backend plugs in here.
//! * [`broadcast`] — the model-version-keyed [`BroadcastCache`]: all three
//!   schedulers encode each global-model version at most once, instead of
//!   re-encoding an unchanged dense broadcast every round/dispatch.
//!
//! The round engine ([`crate::coordinator::engine`]) encodes on the client
//! lane, ships frames through the transport, and decodes server-side; the
//! [`CommLedger`](crate::metrics::CommLedger) is charged from the drained
//! frames' lengths. With the default [`NetConfig`] (homogeneous links, no
//! dropout, no deadline) the simulation is byte-for-byte and bit-for-bit
//! identical to the pre-transport accounting.

pub mod broadcast;
pub mod link;
pub mod transport;
pub mod wire;

pub use broadcast::BroadcastCache;
pub use link::{DropoutModel, LinkProfile, NetConfig};
pub use transport::{Loopback, Transport};
