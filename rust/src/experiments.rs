//! Paper experiment harness: one function per table/figure.
//!
//! Each experiment builds configs, runs simulations, writes per-round CSVs
//! under `results/<exp>/`, and prints the same rows/series the paper
//! reports. `docs/EXPERIMENTS.md` catalogues every experiment's knobs,
//! outputs, and how to reproduce the paper's comm-reduction numbers.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use gradestc::config::{
    AvailConfig, CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
    LaneConfig, ModelKind, SchedKind,
};
use gradestc::coordinator::{RoundHookView, Simulation};
use gradestc::diag::{DiagConfig, DiagState};
use gradestc::metrics::recorder::fmt_mb;
use gradestc::metrics::{RunReport, SimilarityProbe};
use gradestc::model::meta::layer_table;
use gradestc::telemetry::export;
use gradestc::telemetry::DiagProbe;
use gradestc::util::args::ArgSpec;

/// Where one run's telemetry artifacts go. `default()` (no sink) leaves
/// telemetry disabled — the span buffers are never allocated and the run
/// takes the exact pre-telemetry code paths.
#[derive(Clone, Debug, Default)]
pub struct TraceSinks {
    /// Chrome `trace_event` JSON path; the `.jsonl` span stream lands
    /// alongside it ([`export::jsonl_sibling`]).
    pub trace: Option<PathBuf>,
    /// Per-round metrics JSON path.
    pub metrics: Option<PathBuf>,
    /// Diagnostics-plane CSV path (`--diag`); arming it installs a
    /// [`DiagProbe`] and adds a `"diag"` section to the metrics JSON.
    pub diag: Option<PathBuf>,
}

impl TraceSinks {
    /// Whether any sink is configured (telemetry should be enabled).
    pub fn enabled(&self) -> bool {
        self.trace.is_some() || self.metrics.is_some() || self.diag.is_some()
    }

    /// Arm telemetry on a freshly built simulation when any sink is set.
    pub fn arm(&self, sim: &mut Simulation) {
        if self.enabled() {
            sim.enable_telemetry();
        }
    }

    /// Install a [`DiagProbe`] on the simulation when the diag sink is
    /// set, returning the shared state to export after the run. Must run
    /// after [`TraceSinks::arm`] (the probe publishes `diag.*` gauges and
    /// `Phase::Diag` spans through the run's telemetry).
    pub fn arm_diag(
        &self,
        sim: &mut Simulation,
        cfg: &ExperimentConfig,
    ) -> Option<Rc<RefCell<DiagState>>> {
        self.diag.as_ref()?;
        let tel = sim.enable_telemetry();
        let probe = DiagProbe::new(cfg, DiagConfig::default()).with_telemetry(tel);
        let state = probe.state();
        sim.set_observer(Box::new(probe));
        Some(state)
    }

    /// Export the configured artifacts from a finished run (no-op when
    /// disabled).
    pub fn export(&self, sim: &Simulation, verbose: bool) -> Result<()> {
        self.export_with_diag(sim, None, verbose)
    }

    /// [`TraceSinks::export`] plus the diagnostics artifacts: the
    /// `diag.csv` table and a `"diag"` section inside the metrics JSON
    /// when both a diag sink and a state are present.
    pub fn export_with_diag(
        &self,
        sim: &Simulation,
        diag: Option<&DiagState>,
        verbose: bool,
    ) -> Result<()> {
        if let (Some(path), Some(state)) = (&self.diag, diag) {
            export::write_diag_csv(state, path)?;
            if verbose {
                println!("diag -> {} ({} rows)", path.display(), state.rows.len());
            }
        }
        let Some(tel) = sim.telemetry() else { return Ok(()) };
        if let Some(path) = &self.trace {
            export::write_chrome_trace(tel, path)?;
            export::write_spans_jsonl(tel, &export::jsonl_sibling(path))?;
            if verbose {
                println!(
                    "trace -> {} (+ .jsonl, {} spans)",
                    path.display(),
                    tel.span_count()
                );
            }
        }
        if let Some(path) = &self.metrics {
            export::write_metrics_json_with_diag(tel, diag, path)?;
            if verbose {
                println!("metrics -> {}", path.display());
            }
        }
        Ok(())
    }
}

/// Run one experiment under its configured scheduler (`cfg.sched`; sync by
/// default — bit-identical to the legacy loop), writing its per-round CSV,
/// and return the report.
pub fn run_one(cfg: &ExperimentConfig, out_dir: &str, verbose: bool) -> Result<RunReport> {
    run_one_traced(cfg, out_dir, verbose, &TraceSinks::default())
}

/// [`run_one`] with telemetry sinks: arms the tracer before the run and
/// exports the trace/metrics artifacts after. Traced records are
/// bit-identical to untraced ones (locked in by `rust/tests/telemetry.rs`).
pub fn run_one_traced(
    cfg: &ExperimentConfig,
    out_dir: &str,
    verbose: bool,
    sinks: &TraceSinks,
) -> Result<RunReport> {
    let t0 = std::time::Instant::now();
    let mut sim = Simulation::build(cfg.clone())
        .with_context(|| format!("building simulation '{}'", cfg.name))?;
    sinks.arm(&mut sim);
    let diag = sinks.arm_diag(&mut sim, cfg);
    let report = sim.run_scheduled_with_progress(|round, rec| {
        if verbose {
            println!(
                "[{}] round {round:>3}: loss {:.4} acc {:>6.2}% uplink {:.3} MB",
                cfg.name,
                rec.train_loss,
                rec.test_accuracy * 100.0,
                rec.uplink_bytes as f64 / 1e6
            );
        }
    })?;
    let csv = PathBuf::from(out_dir).join(format!("{}.csv", cfg.name));
    sim.recorder.write_csv(&csv)?;
    let diag = diag.as_ref().map(|s| s.borrow());
    sinks.export_with_diag(&sim, diag.as_deref(), verbose)?;
    if verbose {
        println!(
            "[{}] done in {:.1}s -> {}",
            cfg.name,
            t0.elapsed().as_secs_f64(),
            csv.display()
        );
    }
    Ok(report)
}

/// `gradestc exp <id>` dispatcher.
pub fn cmd_exp(argv: Vec<String>) -> i32 {
    let (id, rest) = match argv.split_first() {
        Some((c, r)) => (c.clone(), r.to_vec()),
        None => {
            eprintln!(
                "usage: gradestc exp <fig1|fig2|table3|table4|fig7|fig8|fig9|async1|scale1|scale2|diag1|churn1> [opts]"
            );
            return 2;
        }
    };
    let spec = ArgSpec::new("gradestc exp", "regenerate a paper table/figure")
        .opt("out", "results", "results directory")
        .opt("rounds", "0", "override rounds (0 = experiment default)")
        .opt("seed", "7", "rng seed")
        .opt(
            "scale",
            "small",
            "table3 scale: smoke (mnist only) | small (all datasets) | full",
        )
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("samples", "0", "override samples per client (0 = preset default)")
        .opt("eval-every", "1", "evaluate every N rounds")
        .opt("workers", "0", "worker threads for the per-client phase (0 = auto)")
        .opt(
            "clients",
            "0",
            "override the client population (0 = experiment default; scale1: 10000, scale2: 1000000)",
        )
        .opt(
            "trace",
            "",
            "directory for per-run Chrome trace_event JSON (<dir>/<run>.trace.json + .jsonl); empty = telemetry off",
        )
        .opt(
            "metrics",
            "",
            "directory for per-run per-round metrics JSON (<dir>/<run>.metrics.json); empty = off",
        )
        .opt(
            "diag",
            "",
            "directory for per-run diagnostics CSV (<dir>/<run>.diag.csv, plus a 'diag' metrics-JSON section); empty = off (diag1 always arms it)",
        )
        .flag("native", "use the native trainer instead of XLA artifacts")
        .flag("ef", "include the error-feedback extension in table4");
    let args = match spec.parse(rest) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let ctx = ExpCtx {
        out: args.str("out").to_string(),
        rounds_override: args.usize("rounds"),
        seed: args.f64("seed") as u64,
        scale: args.str("scale").to_string(),
        use_xla: !args.has_flag("native"),
        artifacts: args.str("artifacts").to_string(),
        with_ef: args.has_flag("ef"),
        samples: args.usize("samples"),
        eval_every: args.usize("eval-every"),
        workers: args.usize("workers"),
        clients: args.usize("clients"),
        trace_dir: args.str("trace").to_string(),
        metrics_dir: args.str("metrics").to_string(),
        diag_dir: args.str("diag").to_string(),
    };
    let r = match id.as_str() {
        "fig1" => exp_fig1(&ctx),
        "fig2" => exp_fig2(&ctx),
        "table3" => exp_table3(&ctx),
        "table4" => exp_table4(&ctx),
        "fig7" => exp_fig7(&ctx),
        "fig8" => exp_fig8(&ctx),
        "fig9" => exp_fig9(&ctx),
        "async1" => exp_async1(&ctx),
        "scale1" => exp_scale1(&ctx),
        "scale2" => exp_scale2(&ctx),
        "diag1" => exp_diag1(&ctx),
        "churn1" => exp_churn1(&ctx),
        other => {
            eprintln!("unknown experiment '{other}'");
            return 2;
        }
    };
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

struct ExpCtx {
    out: String,
    rounds_override: usize,
    seed: u64,
    scale: String,
    use_xla: bool,
    artifacts: String,
    with_ef: bool,
    samples: usize,
    eval_every: usize,
    workers: usize,
    clients: usize,
    trace_dir: String,
    metrics_dir: String,
    diag_dir: String,
}

impl ExpCtx {
    /// Per-run telemetry sinks: `<trace_dir>/<run>.trace.json` and
    /// `<metrics_dir>/<run>.metrics.json` when the directories are set.
    fn sinks(&self, name: &str) -> TraceSinks {
        TraceSinks {
            trace: (!self.trace_dir.is_empty())
                .then(|| PathBuf::from(&self.trace_dir).join(format!("{name}.trace.json"))),
            metrics: (!self.metrics_dir.is_empty())
                .then(|| PathBuf::from(&self.metrics_dir).join(format!("{name}.metrics.json"))),
            diag: (!self.diag_dir.is_empty())
                .then(|| PathBuf::from(&self.diag_dir).join(format!("{name}.diag.csv"))),
        }
    }

    fn rounds_or(&self, default: usize) -> usize {
        if self.rounds_override > 0 {
            self.rounds_override
        } else {
            default
        }
    }

    fn base(&self, dataset: DatasetKind, dist: DataDistribution, comp: CompressorKind, rounds: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset_table3(dataset, dist, comp, rounds, self.seed);
        cfg.use_xla = self.use_xla;
        cfg.artifacts_dir = self.artifacts.clone();
        if self.samples > 0 {
            cfg.samples_per_client = self.samples;
        }
        if self.eval_every > 1 {
            cfg.eval_every = self.eval_every;
        }
        // Bit-identical for any worker count, so experiment outputs stay
        // reproducible regardless of this knob.
        cfg.workers = self.workers;
        cfg
    }
}

/// One held-out test set per `(dataset, test_samples, seed)` triple, shared
/// across an experiment grid's cells. Cells in one grid differ in
/// compressor, scheduler, or shard distribution — none of which touch the
/// evaluation set — so the first cell's [`Simulation::test_data`] is handed
/// to every later build instead of being regenerated (and its samples
/// cloned) per run.
struct TestSetCache {
    entries: Vec<((DatasetKind, usize, u64), Arc<gradestc::data::Dataset>)>,
}

impl TestSetCache {
    fn new() -> Self {
        TestSetCache { entries: Vec::new() }
    }

    /// [`Simulation::build`], reusing the cached test set on a key hit and
    /// caching this build's on a miss.
    fn build(&mut self, cfg: &ExperimentConfig) -> Result<Simulation> {
        let key = (cfg.dataset, cfg.test_samples, cfg.seed);
        let shared =
            self.entries.iter().find(|(k, _)| *k == key).map(|(_, t)| Arc::clone(t));
        let hit = shared.is_some();
        let sim = Simulation::build_with_test_data(cfg.clone(), shared)
            .with_context(|| format!("building simulation '{}'", cfg.name))?;
        if !hit {
            self.entries.push((key, Arc::clone(&sim.test_data)));
        }
        Ok(sim)
    }
}

// ---------------------------------------------------------------------------
// Fig. 1 — temporal-correlation heatmaps
// ---------------------------------------------------------------------------

fn exp_fig1(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig. 1: cosine-similarity heatmaps of one client's gradient stream ==");
    let rounds = ctx.rounds_or(40);
    let mut cfg = ctx.base(
        DatasetKind::SynthCifar10,
        DataDistribution::Iid,
        CompressorKind::None,
        rounds,
    );
    cfg.name = "fig1-similarity".into();
    cfg.eval_every = usize::MAX; // no evaluation: we only probe gradients
    let meta = layer_table(cfg.model);
    let probed: Vec<usize> = (0..meta.layers.len())
        .filter(|&i| meta.layers[i].compressible())
        .collect();
    let names: Vec<String> =
        probed.iter().map(|&i| meta.layers[i].name.clone()).collect();
    let probe = std::rc::Rc::new(std::cell::RefCell::new(SimilarityProbe::new(
        names.clone(),
    )));
    let probe2 = probe.clone();
    let probed2 = probed.clone();

    let sinks = ctx.sinks(&cfg.name);
    let mut sim = Simulation::build(cfg.clone())?;
    sinks.arm(&mut sim);
    sim.set_round_hook(Box::new(move |_round, view: &RoundHookView| {
        // Client 0's raw update per layer (FedAvg → decompressed == raw).
        if let Some((_, tensors)) = view.updates.iter().find(|(id, _)| *id == 0) {
            let grads: Vec<Vec<f32>> =
                probed2.iter().map(|&i| tensors[i].clone()).collect();
            probe2.borrow_mut().record_round(grads);
        }
    }));
    for round in 0..cfg.rounds {
        let rec = sim.step(round)?;
        println!("round {round:>3}: loss {:.4}", rec.train_loss);
    }
    sinks.export(&sim, false)?;

    let out = PathBuf::from(&ctx.out).join("fig1");
    std::fs::create_dir_all(&out)?;
    let probe = probe.borrow();
    for &r in &[5usize, 10, 15, 20, 25, 30] {
        if r < probe.rounds() {
            std::fs::write(out.join(format!("heatmap_vs_r{r}.csv")), probe.heatmap_csv(r))?;
        }
    }
    // Headline summary: adjacent-round similarity per layer, and the
    // parameter-dominant vs rest contrast the paper highlights.
    let adj = probe.adjacent_similarity();
    let mut rows: Vec<(String, usize, f64)> = names
        .iter()
        .zip(&probed)
        .zip(&adj)
        .map(|((n, &i), &s)| (n.clone(), meta.layers[i].size(), s))
        .collect();
    println!("\nlayer, params, mean adjacent-round cosine");
    for (n, sz, s) in &rows {
        println!("{n:<28} {sz:>8} {s:>7.4}");
    }
    rows.sort_by_key(|&(_, sz, _)| std::cmp::Reverse(sz));
    let big: Vec<&(String, usize, f64)> = rows.iter().take(4).collect();
    let big_mean: f64 = big.iter().map(|r| r.2).sum::<f64>() / big.len() as f64;
    let small_mean: f64 = rows.iter().skip(4).map(|r| r.2).sum::<f64>()
        / rows.len().saturating_sub(4).max(1) as f64;
    println!(
        "\nparameter-dominant layers (top 4 by size) mean similarity: {big_mean:.4}\n\
         remaining layers mean similarity:                          {small_mean:.4}\n\
         (paper Fig. 1: dominant layers show the stronger temporal correlation)"
    );
    let mut csv = String::from("layer,params,adjacent_cosine\n");
    for (n, sz, s) in &rows {
        csv.push_str(&format!("{n},{sz},{s:.6}\n"));
    }
    std::fs::write(out.join("adjacent_similarity.csv"), csv)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — per-layer parameter sizes
// ---------------------------------------------------------------------------

fn exp_fig2(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig. 2: parameter size per layer (ResNetLite) ==");
    let meta = layer_table(ModelKind::ResNetLite);
    let total = meta.total_params();
    let out = PathBuf::from(&ctx.out).join("fig2");
    std::fs::create_dir_all(&out)?;
    let mut csv = String::from("index,layer,params,cumulative_frac\n");
    let mut cum = 0usize;
    for (i, l) in meta.layers.iter().enumerate() {
        cum += l.size();
        println!("{i:>3} {:<28} {:>8}", l.name, l.size());
        csv.push_str(&format!(
            "{i},{},{},{:.4}\n",
            l.name,
            l.size(),
            cum as f64 / total as f64
        ));
    }
    let set = meta.compression_set(0.9);
    let covered: usize = set.iter().map(|&i| meta.layers[i].size()).sum();
    println!(
        "\ntotal params: {total}; compressed layers ({} of {}) hold {:.1}% \
         (paper §V-B: 92.3% for ResNet18 stage3/4)",
        set.len(),
        meta.layers.len(),
        100.0 * covered as f64 / total as f64
    );
    std::fs::write(out.join("layer_sizes.csv"), csv)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table III (and Figs. 4/5/6 data) — main comparison grid
// ---------------------------------------------------------------------------

fn methods_for_dataset(d: DatasetKind) -> Vec<(String, CompressorKind)> {
    let k = match d {
        DatasetKind::SynthMnist => 8,
        _ => 32,
    };
    vec![
        ("fedavg".into(), CompressorKind::None),
        ("topk".into(), CompressorKind::TopK { frac: 0.1 }),
        ("fedpaq".into(), CompressorKind::FedPaq { bits: 8 }),
        ("svdfed".into(), CompressorKind::SvdFed { k, gamma: 0.5 }),
        ("fedqclip".into(), CompressorKind::FedQClip { bits: 8, clip: 2.5 }),
        (
            "gradestc".into(),
            CompressorKind::GradEstc(GradEstcParams { k, ..Default::default() }),
        ),
    ]
}

fn exp_table3(ctx: &ExpCtx) -> Result<()> {
    println!("== Table III / Figs. 4-6: main comparison (scale: {}) ==", ctx.scale);
    let (datasets, default_rounds): (Vec<DatasetKind>, usize) = match ctx.scale.as_str() {
        "smoke" => (vec![DatasetKind::SynthMnist], 8),
        "cifar10" => (vec![DatasetKind::SynthCifar10], 12),
        "cifar100" => (vec![DatasetKind::SynthCifar100], 10),
        "small" => (
            vec![
                DatasetKind::SynthMnist,
                DatasetKind::SynthCifar10,
                DatasetKind::SynthCifar100,
            ],
            20,
        ),
        "full" => (
            vec![
                DatasetKind::SynthMnist,
                DatasetKind::SynthCifar10,
                DatasetKind::SynthCifar100,
            ],
            40,
        ),
        other => anyhow::bail!("unknown scale '{other}'"),
    };
    let dists = [
        ("iid", DataDistribution::Iid),
        ("dir0.5", DataDistribution::Dirichlet(0.5)),
        ("dir0.1", DataDistribution::Dirichlet(0.1)),
    ];
    let rounds = ctx.rounds_or(default_rounds);
    let out = PathBuf::from(&ctx.out).join("table3");
    std::fs::create_dir_all(&out)?;
    let mut summary = String::from(
        "dataset,dist,method,uplink_at_threshold_mb,total_uplink_mb,best_acc,threshold\n",
    );
    println!(
        "\n{:<14} {:<7} {:<10} {:>14} {:>12} {:>9}",
        "dataset", "dist", "method", "up@thresh MB", "total MB", "best acc"
    );
    // Grid cells share one held-out test set per dataset (shards differ
    // between cells; the evaluation set never does).
    let mut tests = TestSetCache::new();
    for &dataset in &datasets {
        for (dname, dist) in dists {
            // FedAvg first: its best accuracy anchors the threshold all
            // methods chase (scaled analog of the paper's fixed level).
            let mut reports: Vec<(String, RunReport)> = Vec::new();
            let mut threshold = 0.0f64;
            for (mname, comp) in methods_for_dataset(dataset) {
                let mut cfg = ctx.base(dataset, dist, comp, rounds);
                cfg.name = format!(
                    "table3-{}-{}-{}",
                    gradestc::config::experiment::dataset_name(dataset),
                    dname,
                    mname
                );
                let sinks = ctx.sinks(&cfg.name);
                let mut sim = tests.build(&cfg)?;
                sinks.arm(&mut sim);
                let diag = sinks.arm_diag(&mut sim, &cfg);
                let rep = sim.run_with_progress(|_, _| {})?;
                sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
                let diag = diag.as_ref().map(|s| s.borrow());
                sinks.export_with_diag(&sim, diag.as_deref(), false)?;
                if mname == "fedavg" {
                    threshold = cfg.threshold_frac * rep.best_accuracy;
                }
                // Re-derive the report against the shared threshold.
                let rep = sim.recorder.report(threshold);
                println!(
                    "{:<14} {:<7} {:<10} {:>14} {:>12} {:>8.2}%",
                    gradestc::config::experiment::dataset_name(dataset),
                    dname,
                    mname,
                    rep.uplink_at_threshold
                        .map(fmt_mb)
                        .unwrap_or_else(|| "-".into()),
                    fmt_mb(rep.total_uplink),
                    rep.best_accuracy * 100.0
                );
                summary.push_str(&format!(
                    "{},{},{},{},{},{:.4},{:.4}\n",
                    gradestc::config::experiment::dataset_name(dataset),
                    dname,
                    mname,
                    rep.uplink_at_threshold.map(fmt_mb).unwrap_or_default(),
                    fmt_mb(rep.total_uplink),
                    rep.best_accuracy,
                    threshold
                ));
                reports.push((mname, rep));
            }
            // The paper's headline: GradESTC's uplink-at-threshold vs the
            // strongest baseline's.
            let g = reports.iter().find(|(n, _)| n == "gradestc");
            let best_baseline = reports
                .iter()
                .filter(|(n, _)| n != "gradestc" && n != "fedavg")
                .filter_map(|(n, r)| r.uplink_at_threshold.map(|u| (n.clone(), u)))
                .min_by_key(|&(_, u)| u);
            if let (Some((_, g)), Some((bn, bu))) = (g, best_baseline) {
                if let Some(gu) = g.uplink_at_threshold {
                    println!(
                        "  -> GradESTC uplink@threshold vs best baseline ({bn}): \
                         {:.1}% reduction",
                        100.0 * (1.0 - gu as f64 / bu as f64)
                    );
                }
            }
        }
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    println!("\nper-round CSVs in {} (Figs. 4/5/6 series)", out.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV — ablation
// ---------------------------------------------------------------------------

fn exp_table4(ctx: &ExpCtx) -> Result<()> {
    println!("== Table IV: ablation (GradESTC variants, synth-CIFAR10) ==");
    let rounds = ctx.rounds_or(20);
    let out = PathBuf::from(&ctx.out).join("table4");
    std::fs::create_dir_all(&out)?;
    let k = 32;
    let mut variants: Vec<(&str, GradEstcParams)> = vec![
        (
            "gradestc-first",
            GradEstcParams { k, freeze_after_init: true, ..Default::default() },
        ),
        ("gradestc-all", GradEstcParams { k, replace_all: true, ..Default::default() }),
        ("gradestc-k", GradEstcParams { k, fixed_d: true, ..Default::default() }),
        ("gradestc", GradEstcParams { k, ..Default::default() }),
    ];
    if ctx.with_ef {
        variants.push((
            "gradestc+ef",
            GradEstcParams { k, error_feedback: true, ..Default::default() },
        ));
    }

    // Anchor threshold at 70% of the uncompressed best (paper uses the 70%
    // absolute-accuracy mark).
    let mut cfg0 = ctx.base(
        DatasetKind::SynthCifar10,
        DataDistribution::Iid,
        CompressorKind::None,
        rounds,
    );
    cfg0.name = "table4-fedavg".into();
    // Every ablation cell evaluates on the anchor's test set.
    let mut tests = TestSetCache::new();
    let sinks0 = ctx.sinks(&cfg0.name);
    let mut sim0 = tests.build(&cfg0)?;
    sinks0.arm(&mut sim0);
    let rep0 = sim0.run_with_progress(|_, _| {})?;
    sim0.recorder.write_csv(&out.join("table4-fedavg.csv"))?;
    sinks0.export(&sim0, false)?;
    let threshold = 0.70 * rep0.best_accuracy;

    let mut summary =
        String::from("method,best_acc,uplink_at_70_mb,total_uplink_mb,sum_d\n");
    println!(
        "\n{:<16} {:>9} {:>14} {:>12} {:>10}",
        "method", "best acc", "up@70% MB", "total MB", "sum d"
    );
    for (name, params) in variants {
        let mut cfg = ctx.base(
            DatasetKind::SynthCifar10,
            DataDistribution::Iid,
            CompressorKind::GradEstc(params),
            rounds,
        );
        cfg.name = format!("table4-{name}");
        let sinks = ctx.sinks(&cfg.name);
        let mut sim = tests.build(&cfg)?;
        sinks.arm(&mut sim);
        let diag = sinks.arm_diag(&mut sim, &cfg);
        sim.run_with_progress(|_, _| {})?;
        sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
        let diag = diag.as_ref().map(|s| s.borrow());
        sinks.export_with_diag(&sim, diag.as_deref(), false)?;
        let rep = sim.recorder.report(threshold);
        println!(
            "{:<16} {:>8.2}% {:>14} {:>12} {:>10}",
            name,
            rep.best_accuracy * 100.0,
            rep.uplink_at_threshold.map(fmt_mb).unwrap_or_else(|| "-".into()),
            fmt_mb(rep.total_uplink),
            rep.sum_d
        );
        summary.push_str(&format!(
            "{},{:.4},{},{},{}\n",
            name,
            rep.best_accuracy,
            rep.uplink_at_threshold.map(fmt_mb).unwrap_or_default(),
            fmt_mb(rep.total_uplink),
            rep.sum_d
        ));
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7 — 50 clients, 20% participation
// ---------------------------------------------------------------------------

fn exp_fig7(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig. 7: 50 clients, 20% participation (synth-CIFAR10) ==");
    let rounds = ctx.rounds_or(30);
    let out = PathBuf::from(&ctx.out).join("fig7");
    std::fs::create_dir_all(&out)?;
    for (name, comp) in [
        ("fedavg", CompressorKind::None),
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 32, ..Default::default() }),
        ),
    ] {
        let mut cfg = ctx.base(
            DatasetKind::SynthCifar10,
            DataDistribution::Dirichlet(0.5),
            comp,
            rounds,
        );
        cfg.name = format!("fig7-{name}");
        cfg.num_clients = 50;
        cfg.participation = 0.2;
        cfg.samples_per_client = 128;
        let rep = run_one_traced(&cfg, out.to_str().unwrap(), false, &ctx.sinks(&cfg.name))?;
        println!(
            "{name:<10} best acc {:.2}% total uplink {} MB",
            rep.best_accuracy * 100.0,
            fmt_mb(rep.total_uplink)
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 — local-epoch sweep
// ---------------------------------------------------------------------------

fn exp_fig8(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig. 8: local epochs 3/5/7 (synth-CIFAR10, GradESTC vs FedAvg) ==");
    let rounds = ctx.rounds_or(15);
    let out = PathBuf::from(&ctx.out).join("fig8");
    std::fs::create_dir_all(&out)?;
    for epochs in [3usize, 5, 7] {
        for (name, comp) in [
            ("fedavg", CompressorKind::None),
            (
                "gradestc",
                CompressorKind::GradEstc(GradEstcParams { k: 32, ..Default::default() }),
            ),
        ] {
            let mut cfg = ctx.base(
                DatasetKind::SynthCifar10,
                DataDistribution::Iid,
                comp,
                rounds,
            );
            cfg.name = format!("fig8-e{epochs}-{name}");
            cfg.local_epochs = epochs;
            let rep = run_one_traced(&cfg, out.to_str().unwrap(), false, &ctx.sinks(&cfg.name))?;
            println!(
                "epochs {epochs} {name:<10} best acc {:.2}% total uplink {} MB",
                rep.best_accuracy * 100.0,
                fmt_mb(rep.total_uplink)
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 — k sensitivity
// ---------------------------------------------------------------------------

fn exp_fig9(ctx: &ExpCtx) -> Result<()> {
    println!("== Fig. 9: k sensitivity (synth-CIFAR10, GradESTC) ==");
    let rounds = ctx.rounds_or(20);
    let out = PathBuf::from(&ctx.out).join("fig9");
    std::fs::create_dir_all(&out)?;
    println!(
        "{:<6} {:>9} {:>12} {:>10}",
        "k", "best acc", "total MB", "sum d"
    );
    for k in [8usize, 16, 32, 64, 128] {
        let mut cfg = ctx.base(
            DatasetKind::SynthCifar10,
            DataDistribution::Iid,
            CompressorKind::GradEstc(GradEstcParams { k, ..Default::default() }),
            rounds,
        );
        cfg.name = format!("fig9-k{k}");
        let rep = run_one_traced(&cfg, out.to_str().unwrap(), false, &ctx.sinks(&cfg.name))?;
        println!(
            "{k:<6} {:>8.2}% {:>12} {:>10}",
            rep.best_accuracy * 100.0,
            fmt_mb(rep.total_uplink),
            rep.sum_d
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// async1 — time-to-accuracy under the scheduler plane
// ---------------------------------------------------------------------------

/// The scheduler-plane headline: under heterogeneous links
/// (`het_spread = 1.0`), compare virtual time-to-target-accuracy for
/// GradESTC vs FedAvg/TopK under sync, semi-sync (deadline + straggler
/// rollover), and async-buffered (`k = n/2`, staleness 0.5) control flows.
/// Sync waits for the slowest client every round; async applies at the
/// pace of the `k` fastest arrivals, so it reaches the same accuracy bar
/// in strictly less virtual time.
fn exp_async1(ctx: &ExpCtx) -> Result<()> {
    println!(
        "== async1: time-to-target accuracy, sync vs semisync vs async (het links) =="
    );
    let rounds = ctx.rounds_or(12);
    let out = PathBuf::from(&ctx.out).join("async1");
    std::fs::create_dir_all(&out)?;

    let mk_base = |comp: CompressorKind| -> ExperimentConfig {
        let mut cfg = ctx.base(DatasetKind::SynthMnist, DataDistribution::Iid, comp, rounds);
        cfg.num_clients = 8;
        cfg.samples_per_client = 128;
        // The heterogeneous-link regime the scheduler plane exists for.
        cfg.net.het_spread = 1.0;
        cfg
    };

    // Semi-sync deadline: 1.5× the mean link's dense-model round trip —
    // fast clients make it comfortably, the slow tail rolls over.
    let probe = mk_base(CompressorKind::None);
    let meta = layer_table(probe.model);
    let model_bytes = 4 * meta.total_params() as u64;
    let deadline = 1.5 * probe.net.base_profile().round_trip_time(model_bytes, model_bytes);
    let k_async = (probe.num_clients / 2).max(1);

    let scheds: Vec<(&str, SchedKind, f64)> = vec![
        ("sync", SchedKind::Sync, 0.0),
        ("semisync", SchedKind::SemiSync, deadline),
        ("async", SchedKind::Async { k: k_async, staleness_p: 0.5 }, 0.0),
    ];
    let methods: Vec<(&str, CompressorKind)> = vec![
        ("fedavg", CompressorKind::None),
        ("topk", CompressorKind::TopK { frac: 0.1 }),
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
    ];

    // Anchor: the target accuracy every (method, scheduler) pair chases is
    // threshold_frac × the sync FedAvg run's best accuracy (first cell).
    let mut target = 0.0f64;
    let mut summary = String::from(
        "method,sched,target_acc,time_to_target_s,rounds_to_target,total_sim_time_s,best_acc,total_uplink_mb\n",
    );
    println!(
        "\n{:<10} {:<9} {:>15} {:>7} {:>14} {:>9} {:>11}",
        "method", "sched", "t→target (s)", "rounds", "total vtime", "best acc", "uplink MB"
    );
    let mut times: Vec<(String, String, Option<f64>)> = Vec::new();
    let mut tests = TestSetCache::new();
    for (mname, comp) in &methods {
        for (sname, skind, dl) in &scheds {
            let mut cfg = mk_base(comp.clone());
            cfg.name = format!("async1-{mname}-{sname}");
            cfg.net.deadline_s = *dl;
            cfg.sched.kind = *skind;
            let sinks = ctx.sinks(&cfg.name);
            let mut sim = tests.build(&cfg)?;
            sinks.arm(&mut sim);
            let diag = sinks.arm_diag(&mut sim, &cfg);
            let rep = sim.run_scheduled_with_progress(|_, _| {})?;
            sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
            let diag = diag.as_ref().map(|s| s.borrow());
            sinks.export_with_diag(&sim, diag.as_deref(), false)?;
            if *mname == "fedavg" && *sname == "sync" {
                target = cfg.threshold_frac * rep.best_accuracy;
            }
            let recs = sim.recorder.rounds();
            let hit = recs
                .iter()
                .find(|r| !r.test_accuracy.is_nan() && r.test_accuracy >= target);
            let t_target = hit.map(|r| r.sim_clock_s);
            let total_vtime = recs.last().map(|r| r.sim_clock_s).unwrap_or(0.0);
            println!(
                "{:<10} {:<9} {:>15} {:>7} {:>13.2}s {:>8.2}% {:>11}",
                mname,
                sname,
                t_target.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into()),
                hit.map(|r| format!("{}", r.round)).unwrap_or_else(|| "-".into()),
                total_vtime,
                rep.best_accuracy * 100.0,
                fmt_mb(rep.total_uplink),
            );
            summary.push_str(&format!(
                "{},{},{:.4},{},{},{:.4},{:.4},{}\n",
                mname,
                sname,
                target,
                t_target.map(|t| format!("{t:.4}")).unwrap_or_default(),
                hit.map(|r| format!("{}", r.round)).unwrap_or_default(),
                total_vtime,
                rep.best_accuracy,
                fmt_mb(rep.total_uplink),
            ));
            times.push((mname.to_string(), sname.to_string(), t_target));
        }
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    // The acceptance headline: async vs sync virtual time-to-target.
    for (mname, _) in &methods {
        let get = |s: &str| {
            times
                .iter()
                .find(|(m, sc, _)| m == mname && sc == s)
                .and_then(|(_, _, t)| *t)
        };
        if let (Some(ts), Some(ta)) = (get("sync"), get("async")) {
            println!(
                "  -> {mname}: async hits the target in {:.1}% of sync's virtual time \
                 ({ta:.2}s vs {ts:.2}s)",
                100.0 * ta / ts
            );
        }
    }
    println!("\nper-round CSVs in {} (x-axis: sim_clock_s)", out.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// scale1 — 10⁴-client populations on the shared-basis lane pool
// ---------------------------------------------------------------------------

/// The basis-pool headline: a 10k-client GradESTC population with sampled
/// participation (~100 concurrent clients) under the sync and async
/// control flows. Server-side basis state is interned in the shared
/// [`BasisPool`](gradestc::compress::BasisPool), so resident basis memory
/// follows the *participants*, not the population — the dedup factor vs
/// the naive `clients × basis` baseline is what this experiment reports
/// (`docs/EXPERIMENTS.md` catalogues the knobs and outputs).
fn exp_scale1(ctx: &ExpCtx) -> Result<()> {
    let clients = if ctx.clients > 0 { ctx.clients } else { 10_000 };
    let concurrent = 100.min(clients);
    let rounds = ctx.rounds_or(3);
    println!(
        "== scale1: {clients} clients, ~{concurrent} concurrent, {rounds} rounds \
         (sync vs async on the shared-basis pool) =="
    );
    let out = PathBuf::from(&ctx.out).join("scale1");
    std::fs::create_dir_all(&out)?;

    let mk_base = || -> ExperimentConfig {
        let mut cfg = ctx.base(
            DatasetKind::SynthMnist,
            DataDistribution::Iid,
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
            rounds,
        );
        cfg.num_clients = clients;
        cfg.participation = concurrent as f64 / clients as f64;
        // Tiny shards: the population is the point, not the corpus.
        cfg.samples_per_client = 2;
        cfg.test_samples = 64;
        cfg.net.het_spread = 1.0;
        cfg
    };
    let naive_per_lane = gradestc::compress::gradestc::basis_bytes_per_lane(
        &layer_table(mk_base().model),
        &GradEstcParams { k: 8, ..Default::default() },
    );

    let mut summary = String::from(
        "sched,clients,concurrent,rounds,pool_entries,pool_mb,naive_mb,dedup_x,\
         sim_clock_s,total_uplink_mb,build_s,run_s\n",
    );
    println!(
        "\n{:<9} {:>12} {:>10} {:>10} {:>8} {:>12} {:>9} {:>8}",
        "sched", "pool entry", "pool MB", "naive MB", "dedup", "sim clock", "build s", "run s"
    );
    let k_async = 32.min(concurrent.max(1));
    for (sname, kind) in [
        ("sync", SchedKind::Sync),
        ("async", SchedKind::Async { k: k_async, staleness_p: 0.5 }),
    ] {
        let mut cfg = mk_base();
        cfg.name = format!("scale1-{sname}");
        cfg.sched.kind = kind;
        let sinks = ctx.sinks(&cfg.name);
        let t0 = std::time::Instant::now();
        let mut sim = Simulation::build(cfg.clone())
            .with_context(|| format!("building {clients}-client simulation"))?;
        sinks.arm(&mut sim);
        let diag = sinks.arm_diag(&mut sim, &cfg);
        let build_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let rep = sim.run_scheduled_with_progress(|_, _| {})?;
        let run_s = t1.elapsed().as_secs_f64();
        sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
        let diag = diag.as_ref().map(|s| s.borrow());
        sinks.export_with_diag(&sim, diag.as_deref(), false)?;

        let pool = sim.basis_pool_stats();
        let naive = naive_per_lane as f64 * clients as f64;
        let dedup = naive / pool.bytes().max(1) as f64;
        let clock = sim.recorder.rounds().last().map(|r| r.sim_clock_s).unwrap_or(0.0);
        println!(
            "{:<9} {:>12} {:>10.3} {:>10.1} {:>7.0}x {:>11.2}s {:>8.1} {:>8.1}",
            sname,
            pool.entries,
            pool.bytes() as f64 / 1e6,
            naive / 1e6,
            dedup,
            clock,
            build_s,
            run_s
        );
        summary.push_str(&format!(
            "{},{},{},{},{},{:.4},{:.4},{:.1},{:.4},{},{:.2},{:.2}\n",
            sname,
            clients,
            concurrent,
            rounds,
            pool.entries,
            pool.bytes() as f64 / 1e6,
            naive / 1e6,
            dedup,
            clock,
            fmt_mb(rep.total_uplink),
            build_s,
            run_s
        ));
        // The acceptance bar this experiment exists for: resident basis
        // state follows the *dispatched lanes*, never the population.
        // Sync samples `concurrent` lanes per round; async dispatches the
        // initial cohort plus one refill per arrival. Each distinct lane
        // contributes at most one lane's worth of live basis bytes when
        // interning dedupes and stale COW generations are released — so
        // this bound holds for any `--clients`/`--rounds` override.
        let max_lanes = match sname {
            "sync" => concurrent * rounds,
            _ => concurrent + k_async * rounds,
        };
        anyhow::ensure!(
            pool.bytes() <= max_lanes * naive_per_lane,
            "basis pool holds {} bytes — more than {max_lanes} dispatched lanes' worth \
             ({} bytes): interning is not deduping",
            pool.bytes(),
            max_lanes * naive_per_lane
        );
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    println!(
        "\nper-round CSVs + summary.csv in {} (columns incl. sim_clock_s, n_survivors)",
        out.display()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// scale2 — 10⁶-client populations on virtual lanes with bounded residency
// ---------------------------------------------------------------------------

/// The virtual-lane headline: a million-client GradESTC population at ~10²
/// concurrency, run with lazy lanes and an LRU residency cap of
/// 2× the concurrent cohort. Sampled-never clients cost ~0 bytes (a lane
/// materializes from `(seed, cid)` only on first dispatch), evicted lanes
/// re-materialize bit-identically on their next dispatch, and the hard
/// `ensure!` below fails the run if resident lanes ever exceed the cap —
/// the residency bound holds for any `--clients`/`--rounds` override.
/// `docs/EXPERIMENTS.md` catalogues the knobs and the summary.csv columns.
fn exp_scale2(ctx: &ExpCtx) -> Result<()> {
    let clients = if ctx.clients > 0 { ctx.clients } else { 1_000_000 };
    let concurrent = 100.min(clients);
    let cap = 2 * concurrent;
    let rounds = ctx.rounds_or(3);
    println!(
        "== scale2: {clients} clients, ~{concurrent} concurrent, lane cap {cap}, \
         {rounds} rounds (lazy virtual lanes, sync vs async) =="
    );
    let out = PathBuf::from(&ctx.out).join("scale2");
    std::fs::create_dir_all(&out)?;

    let mk_base = || -> ExperimentConfig {
        let mut cfg = ctx.base(
            DatasetKind::SynthMnist,
            DataDistribution::Iid,
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
            rounds,
        );
        cfg.num_clients = clients;
        cfg.participation = concurrent as f64 / clients as f64;
        // Tiny shards: the population is the point, not the corpus.
        cfg.samples_per_client = 2;
        cfg.test_samples = 64;
        cfg.net.het_spread = 1.0;
        cfg.lanes = LaneConfig { lazy: true, max_resident: cap, legacy_shards: false };
        cfg
    };
    let naive_per_lane = gradestc::compress::gradestc::basis_bytes_per_lane(
        &layer_table(mk_base().model),
        &GradEstcParams { k: 8, ..Default::default() },
    );

    let mut summary = String::from(
        "sched,clients,concurrent,cap,rounds,resident,materialized,evictions,\
         resident_mb,pool_mb,naive_mb,rss_peak_mb,sim_clock_s,total_uplink_mb,\
         build_s,run_s\n",
    );
    println!(
        "\n{:<9} {:>9} {:>13} {:>9} {:>12} {:>9} {:>12} {:>8} {:>8}",
        "sched", "resident", "materialized", "evicted", "resident MB", "naive MB",
        "peak RSS MB", "build s", "run s"
    );
    let k_async = 32.min(concurrent.max(1));
    // The two runs share one held-out test set (only shards and the
    // scheduler differ between the cells).
    let mut tests = TestSetCache::new();
    for (sname, kind) in [
        ("sync", SchedKind::Sync),
        ("async", SchedKind::Async { k: k_async, staleness_p: 0.5 }),
    ] {
        let mut cfg = mk_base();
        cfg.name = format!("scale2-{sname}");
        cfg.sched.kind = kind;
        let sinks = ctx.sinks(&cfg.name);
        let t0 = std::time::Instant::now();
        let mut sim = tests
            .build(&cfg)
            .with_context(|| format!("building {clients}-client simulation"))?;
        sinks.arm(&mut sim);
        let diag = sinks.arm_diag(&mut sim, &cfg);
        let build_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let rep = sim.run_scheduled_with_progress(|_, _| {})?;
        let run_s = t1.elapsed().as_secs_f64();
        sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
        let diag = diag.as_ref().map(|s| s.borrow());
        sinks.export_with_diag(&sim, diag.as_deref(), false)?;

        // Per-lane resident-byte estimate: the shard (x as f32 + y as u32)
        // plus one lane's worth of basis state. Lane RNG/handles are O(1).
        let feat = sim.test_data.features;
        let lane_bytes =
            cfg.samples_per_client * (feat * 4 + 4) + naive_per_lane;
        let resident = sim.lanes.resident();
        let materialized = sim.lanes.materializations();
        let evictions = sim.lanes.eviction_count();
        let pool = sim.basis_pool_stats();
        let naive = lane_bytes as f64 * clients as f64;
        let rss_peak = peak_rss_mb();
        let clock =
            sim.recorder.rounds().last().map(|r| r.sim_clock_s).unwrap_or(0.0);
        println!(
            "{:<9} {:>9} {:>13} {:>9} {:>12.2} {:>9.0} {:>12.1} {:>8.1} {:>8.1}",
            sname,
            resident,
            materialized,
            evictions,
            resident as f64 * lane_bytes as f64 / 1e6,
            naive / 1e6,
            rss_peak,
            build_s,
            run_s
        );
        summary.push_str(&format!(
            "{},{},{},{},{},{},{},{},{:.4},{:.4},{:.1},{:.1},{:.4},{},{:.2},{:.2}\n",
            sname,
            clients,
            concurrent,
            cap,
            rounds,
            resident,
            materialized,
            evictions,
            resident as f64 * lane_bytes as f64 / 1e6,
            pool.bytes() as f64 / 1e6,
            naive / 1e6,
            rss_peak,
            clock,
            fmt_mb(rep.total_uplink),
            build_s,
            run_s
        ));
        // The acceptance bar this experiment exists for: resident lane
        // bytes are bounded by the eviction cap, never the population.
        anyhow::ensure!(
            resident <= cap,
            "{resident} lanes resident after the run — the LRU cap is {cap}: \
             eviction is not holding the residency bound"
        );
        // And materialization follows dispatches, not the population:
        // sampled-never clients must have cost nothing.
        anyhow::ensure!(
            (materialized as usize) < clients || clients <= cap,
            "materialized {materialized} lanes out of {clients} clients — \
             lazy lanes materialized the whole population"
        );
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    println!(
        "\nper-round CSVs + summary.csv in {} (resident lanes vs cap, peak RSS)",
        out.display()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// diag1 — the gradient-structure observatory
// ---------------------------------------------------------------------------

/// Mean of the `Some` values of `f` over the aggregate (`layer == "*"`)
/// rows of a run's diagnostics.
fn diag_agg_mean(state: &DiagState, f: impl Fn(&gradestc::diag::DiagRow) -> Option<f64>) -> Option<f64> {
    let vals: Vec<f64> =
        state.rows.iter().filter(|r| r.layer == "*").filter_map(&f).collect();
    (!vals.is_empty()).then(|| vals.iter().sum::<f64>() / vals.len() as f64)
}

/// The diagnostics-plane headline: run GradESTC vs SVDFed vs TopK under
/// sync, semi-sync, and async control flows with the [`DiagProbe`] armed,
/// and report how the structural premises respond to staleness — basis
/// drift (principal angles / chordal distance / churn), adjacent-arrival
/// cosine, reconstruction NRMSE under the previous basis, and cumulative
/// uplink bytes per unit of loss decrease. Every cell writes
/// `<out>/diag1/<run>.diag.csv` plus a metrics JSON with the `"diag"`
/// section (validated by `scripts/check_diag.py` in the diag-smoke CI
/// job); `--diag`/`--trace` directories add the usual artifacts on top.
fn exp_diag1(ctx: &ExpCtx) -> Result<()> {
    println!(
        "== diag1: gradient-structure observatory — drift/cosine/NRMSE vs scheduler =="
    );
    let rounds = ctx.rounds_or(12);
    let out = PathBuf::from(&ctx.out).join("diag1");
    std::fs::create_dir_all(&out)?;

    let mk_base = |comp: CompressorKind| -> ExperimentConfig {
        let mut cfg = ctx.base(DatasetKind::SynthMnist, DataDistribution::Iid, comp, rounds);
        cfg.num_clients = 8;
        cfg.samples_per_client = 128;
        // Heterogeneous links: the staleness regime the probe is for.
        cfg.net.het_spread = 1.0;
        cfg
    };
    // Same deadline recipe as async1: 1.5× the mean link's dense round trip.
    let anchor = mk_base(CompressorKind::None);
    let meta = layer_table(anchor.model);
    let model_bytes = 4 * meta.total_params() as u64;
    let deadline =
        1.5 * anchor.net.base_profile().round_trip_time(model_bytes, model_bytes);
    let k_async = (anchor.num_clients / 2).max(1);

    let scheds: Vec<(&str, SchedKind, f64)> = vec![
        ("sync", SchedKind::Sync, 0.0),
        ("semisync", SchedKind::SemiSync, deadline),
        ("async", SchedKind::Async { k: k_async, staleness_p: 0.5 }, 0.0),
    ];
    let methods: Vec<(&str, CompressorKind)> = vec![
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
        ("svdfed", CompressorKind::SvdFed { k: 8, gamma: 0.5 }),
        ("topk", CompressorKind::TopK { frac: 0.1 }),
    ];

    let mut summary = String::from(
        "method,sched,mean_drift_angle,mean_drift_chordal,mean_churn_dr,\
         adjacent_cosine,mean_nrmse,mean_energy_coverage,final_bytes_per_loss,\
         best_acc,total_uplink_mb\n",
    );
    println!(
        "\n{:<10} {:<9} {:>11} {:>9} {:>7} {:>8} {:>8} {:>14}",
        "method", "sched", "drift(rad)", "chordal", "churn", "adj cos", "nrmse", "bytes/loss"
    );
    let fmt_opt = |v: Option<f64>| v.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".into());
    // (method, sched) -> headline numbers, for the staleness-response print.
    let mut cells: Vec<(String, String, Option<f64>, Option<f64>)> = Vec::new();
    let mut tests = TestSetCache::new();
    for (mname, comp) in &methods {
        for (sname, skind, dl) in &scheds {
            let mut cfg = mk_base(comp.clone());
            cfg.name = format!("diag1-{mname}-{sname}");
            cfg.net.deadline_s = *dl;
            cfg.sched.kind = *skind;
            // diag1 always arms the probe and the metrics JSON — its CSV
            // and "diag" section *are* the experiment's output. --diag /
            // --trace / --metrics directories override the defaults.
            let mut sinks = ctx.sinks(&cfg.name);
            sinks.diag =
                Some(sinks.diag.unwrap_or_else(|| out.join(format!("{}.diag.csv", cfg.name))));
            sinks.metrics = Some(
                sinks
                    .metrics
                    .unwrap_or_else(|| out.join(format!("{}.metrics.json", cfg.name))),
            );
            let mut sim = tests.build(&cfg)?;
            sinks.arm(&mut sim);
            let diag = sinks
                .arm_diag(&mut sim, &cfg)
                .expect("diag1 always sets a diag sink");
            let rep = sim.run_scheduled_with_progress(|_, _| {})?;
            sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
            let state = diag.borrow();
            sinks.export_with_diag(&sim, Some(&state), false)?;

            let drift = diag_agg_mean(&state, |r| r.drift_mean_angle);
            let chordal = diag_agg_mean(&state, |r| r.drift_chordal);
            let churn = diag_agg_mean(&state, |r| r.churn_dr.map(|c| c as f64));
            let cos = diag_agg_mean(&state, |r| r.cosine);
            let nrmse = diag_agg_mean(&state, |r| r.nrmse);
            let cover = diag_agg_mean(&state, |r| r.energy_coverage);
            let bpl = state
                .rows
                .iter()
                .filter(|r| r.layer == "*")
                .filter_map(|r| r.bytes_per_loss)
                .last();
            println!(
                "{:<10} {:<9} {:>11} {:>9} {:>7} {:>8} {:>8} {:>14}",
                mname,
                sname,
                fmt_opt(drift),
                fmt_opt(chordal),
                fmt_opt(churn),
                fmt_opt(cos),
                fmt_opt(nrmse),
                bpl.map(|b| format!("{b:.0}")).unwrap_or_else(|| "-".into()),
            );
            summary.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{:.4},{}\n",
                mname,
                sname,
                fmt_opt(drift),
                fmt_opt(chordal),
                fmt_opt(churn),
                fmt_opt(cos),
                fmt_opt(nrmse),
                fmt_opt(cover),
                bpl.map(|b| format!("{b:.2}")).unwrap_or_default(),
                rep.best_accuracy,
                fmt_mb(rep.total_uplink),
            ));
            cells.push((mname.to_string(), sname.to_string(), drift, cos));
        }
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    // The headline: does staleness erode the paper's premises? Compare
    // each method's drift/correlation under async vs sync.
    for (mname, _) in &methods {
        let get = |s: &str| cells.iter().find(|(m, sc, _, _)| m == mname && sc == s);
        if let (Some((_, _, ds, cs)), Some((_, _, da, ca))) = (get("sync"), get("async")) {
            if let (Some(ds), Some(da)) = (ds, da) {
                println!(
                    "  -> {mname}: basis drift {ds:.4} rad (sync) vs {da:.4} rad (async, \
                     staleness-discounted folds)"
                );
            }
            if let (Some(cs), Some(ca)) = (cs, ca) {
                println!(
                    "  -> {mname}: adjacent cosine {cs:.4} (sync) vs {ca:.4} (async)"
                );
            }
        }
    }
    println!(
        "\nper-run diag.csv + metrics JSON in {} (checked by scripts/check_diag.py)",
        out.display()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// churn1 — availability & churn under the async buffer
// ---------------------------------------------------------------------------

/// The availability-plane headline: sweep client availability (always-on
/// anchor, diurnal duty cycle, diurnal + Poisson churn) against the
/// compressor family under the async k-buffered server, and report virtual
/// time-to-target versus the always-on anchor alongside the run's fault
/// count (mid-flight departures, from the `faults` run counter) and the
/// basis-drift diagnostics (mean principal angle, adjacent-arrival cosine).
/// Every cell arms the metrics JSON and the [`DiagProbe`] — the fault
/// counter and drift columns *are* the experiment's output — and the
/// always-on cells double as a live assertion that unarmed availability
/// never faults. `summary.csv` lands in `<out>/churn1/`; the churn-smoke
/// CI job runs this at 3 rounds and gates it with `scripts/check_diag.py`.
fn exp_churn1(ctx: &ExpCtx) -> Result<()> {
    println!(
        "== churn1: availability × compressor under the async buffer =="
    );
    let rounds = ctx.rounds_or(12);
    let out = PathBuf::from(&ctx.out).join("churn1");
    std::fs::create_dir_all(&out)?;

    let mk_base = |comp: CompressorKind| -> ExperimentConfig {
        let mut cfg = ctx.base(DatasetKind::SynthMnist, DataDistribution::Iid, comp, rounds);
        cfg.num_clients = 8;
        cfg.samples_per_client = 128;
        // Heterogeneous links: the regime where departures hurt most.
        cfg.net.het_spread = 1.0;
        cfg
    };
    let anchor = mk_base(CompressorKind::None);
    let k_async = (anchor.num_clients / 2).max(1);

    // Availability cells. The 2 s period keeps the on-window
    // (duty × period = 1.2 s) longer than a typical compressed round trip,
    // so armed cells fault visibly without livelocking; churn adds Poisson
    // departures (~1 − e^{−0.1} ≈ 10% per client per window) on top.
    let avails: Vec<(&str, AvailConfig)> = vec![
        ("always-on", AvailConfig::default()),
        ("diurnal", AvailConfig { duty: 0.6, period_s: 2.0, ..Default::default() }),
        (
            "churn",
            AvailConfig { duty: 0.6, period_s: 2.0, churn_per_s: 0.05, outage_s: 1.0 },
        ),
    ];
    let methods: Vec<(&str, CompressorKind)> = vec![
        ("fedavg", CompressorKind::None),
        ("topk", CompressorKind::TopK { frac: 0.1 }),
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
    ];

    // Anchor: every cell chases threshold_frac × the always-on FedAvg
    // run's best accuracy (the first cell).
    let mut target = 0.0f64;
    let mut summary = String::from(
        "method,avail,target_acc,time_to_target_s,rounds_to_target,best_acc,\
         total_uplink_mb,faults,mean_drift_angle,adjacent_cosine\n",
    );
    println!(
        "\n{:<10} {:<10} {:>15} {:>7} {:>9} {:>7} {:>11} {:>8}",
        "method", "avail", "t→target (s)", "rounds", "best acc", "faults", "drift(rad)", "adj cos"
    );
    let fmt_opt = |v: Option<f64>| v.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".into());
    let mut times: Vec<(String, String, Option<f64>)> = Vec::new();
    let mut tests = TestSetCache::new();
    for (mname, comp) in &methods {
        for (aname, avail) in &avails {
            let mut cfg = mk_base(comp.clone());
            cfg.name = format!("churn1-{mname}-{aname}");
            cfg.sched.kind = SchedKind::Async { k: k_async, staleness_p: 0.5 };
            cfg.sched.avail = *avail;
            // churn1 always arms the metrics JSON (fault counter) and the
            // diag probe (drift under churn); --diag/--trace/--metrics
            // directories override the default paths.
            let mut sinks = ctx.sinks(&cfg.name);
            sinks.metrics = Some(
                sinks
                    .metrics
                    .unwrap_or_else(|| out.join(format!("{}.metrics.json", cfg.name))),
            );
            sinks.diag =
                Some(sinks.diag.unwrap_or_else(|| out.join(format!("{}.diag.csv", cfg.name))));
            let mut sim = tests.build(&cfg)?;
            sinks.arm(&mut sim);
            let diag =
                sinks.arm_diag(&mut sim, &cfg).expect("churn1 always sets a diag sink");
            let rep = sim.run_scheduled_with_progress(|_, _| {})?;
            sim.recorder.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
            let state = diag.borrow();
            sinks.export_with_diag(&sim, Some(&state), false)?;

            let faults = sim
                .telemetry()
                .map(|tel| tel.metrics().run_counter("faults"))
                .unwrap_or(0);
            if !avail.armed() && faults != 0 {
                anyhow::bail!(
                    "always-on cell {} reported {faults} faults — unarmed availability \
                     must never fault",
                    cfg.name
                );
            }
            if *mname == "fedavg" && *aname == "always-on" {
                target = cfg.threshold_frac * rep.best_accuracy;
            }
            let drift = diag_agg_mean(&state, |r| r.drift_mean_angle);
            let cos = diag_agg_mean(&state, |r| r.cosine);
            let recs = sim.recorder.rounds();
            let hit = recs
                .iter()
                .find(|r| !r.test_accuracy.is_nan() && r.test_accuracy >= target);
            let t_target = hit.map(|r| r.sim_clock_s);
            println!(
                "{:<10} {:<10} {:>15} {:>7} {:>8.2}% {:>7} {:>11} {:>8}",
                mname,
                aname,
                t_target.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into()),
                hit.map(|r| format!("{}", r.round)).unwrap_or_else(|| "-".into()),
                rep.best_accuracy * 100.0,
                faults,
                fmt_opt(drift),
                fmt_opt(cos),
            );
            summary.push_str(&format!(
                "{},{},{:.4},{},{},{:.4},{},{},{},{}\n",
                mname,
                aname,
                target,
                t_target.map(|t| format!("{t:.4}")).unwrap_or_default(),
                hit.map(|r| format!("{}", r.round)).unwrap_or_default(),
                rep.best_accuracy,
                fmt_mb(rep.total_uplink),
                faults,
                fmt_opt(drift),
                fmt_opt(cos),
            ));
            times.push((mname.to_string(), aname.to_string(), t_target));
        }
    }
    std::fs::write(out.join("summary.csv"), summary)?;
    // The acceptance headline: the churn tax per method — virtual
    // time-to-target under churn vs the always-on anchor.
    for (mname, _) in &methods {
        let get = |a: &str| {
            times
                .iter()
                .find(|(m, av, _)| m == mname && av == a)
                .and_then(|(_, _, t)| *t)
        };
        if let (Some(t0), Some(tc)) = (get("always-on"), get("churn")) {
            println!(
                "  -> {mname}: churn stretches time-to-target to {:.1}% of always-on \
                 ({tc:.2}s vs {t0:.2}s)",
                100.0 * tc / t0
            );
        }
    }
    println!(
        "\nper-run CSVs + metrics/diag artifacts in {} (summary.csv has the fault \
         and drift columns)",
        out.display()
    );
    Ok(())
}

/// Peak resident-set size of this process in MB (`VmHWM` from
/// `/proc/self/status`); 0 on platforms without procfs.
fn peak_rss_mb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<f64>().ok())
        })
        .map(|kb| kb / 1024.0)
        .unwrap_or(0.0)
}

/// Ensure `results/` exists relative to the repo root even when invoked
/// from elsewhere.
#[allow(dead_code)]
fn ensure_dir(p: &Path) -> Result<()> {
    std::fs::create_dir_all(p)?;
    Ok(())
}
