//! Trace and metrics exporters.
//!
//! Three artifacts per traced run:
//!
//! * **Chrome `trace_event` JSON** ([`write_chrome_trace`]) — open in
//!   `chrome://tracing` or <https://ui.perfetto.dev>. Two processes:
//!   pid 1 is host wall-time (real encode/decode/fold cost), pid 2 is
//!   the scheduler's virtual clock (simulated compute + transit, 1 s of
//!   virtual time rendered as 1 s of trace time). On both, tid 0 is the
//!   coordinator and tid `c+1` is client `c`.
//! * **JSONL span stream** ([`write_spans_jsonl`]) — one span object per
//!   line, for ad-hoc `jq`/pandas processing.
//! * **Metrics JSON** ([`write_metrics_json`]) — the
//!   [`Telemetry::metrics_json`] document: run identity, run-level
//!   totals, and one [`super::RoundSnapshot`] per round.
//!
//! Validated by `scripts/check_trace.py` (schema, per-track monotonic
//! timestamps, span nesting) in the CI trace-smoke job.

use std::path::{Path, PathBuf};

use super::{Span, Telemetry};
use crate::config::Json;

/// Host wall-time track.
const PID_HOST: u64 = 1;
/// Virtual-clock track.
const PID_VIRT: u64 = 2;

fn metadata_event(pid: u64, kind: &str, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("name", Json::str(kind)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

struct Ev {
    pid: u64,
    tid: u64,
    ts: f64,
    dur: f64,
    name: &'static str,
    cat: &'static str,
    round: u64,
    client: Option<u32>,
}

impl Ev {
    fn to_json(&self, backend: &str) -> Json {
        let mut args = vec![
            ("round", Json::num(self.round as f64)),
            ("backend", Json::str(backend)),
        ];
        if let Some(c) = self.client {
            args.push(("client", Json::num(c as f64)));
        }
        Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(self.pid as f64)),
            ("tid", Json::num(self.tid as f64)),
            ("ts", Json::num(self.ts)),
            ("dur", Json::num(self.dur)),
            ("name", Json::str(self.name)),
            ("cat", Json::str(self.cat)),
            ("args", Json::obj(args)),
        ])
    }
}

/// Build the Chrome `trace_event` document for everything recorded so far.
pub fn chrome_trace_json(tel: &Telemetry) -> Json {
    let mut evs: Vec<Ev> = Vec::new();
    for s in tel.spans() {
        let Span { phase, round, client, host, virt } = s;
        let tid = client.map(|c| c as u64 + 1).unwrap_or(0);
        if let Some((start_us, dur_us)) = host {
            evs.push(Ev {
                pid: PID_HOST,
                tid,
                ts: start_us as f64,
                dur: dur_us as f64,
                name: phase.name(),
                cat: "host",
                round,
                client,
            });
        }
        if let Some((start_s, end_s)) = virt {
            evs.push(Ev {
                pid: PID_VIRT,
                tid,
                ts: start_s * 1e6,
                dur: (end_s - start_s) * 1e6,
                name: phase.name(),
                cat: "virtual",
                round,
                client,
            });
        }
    }
    // Per-track timestamp order; longer span first on ties so containment
    // nests (parents open before children at the same instant).
    evs.sort_by(|a, b| {
        (a.pid, a.tid)
            .cmp(&(b.pid, b.tid))
            .then(a.ts.total_cmp(&b.ts))
            .then(b.dur.total_cmp(&a.dur))
    });

    let mut events = vec![
        metadata_event(PID_HOST, "process_name", "host wall-time"),
        metadata_event(PID_VIRT, "process_name", "virtual clock"),
        metadata_event(PID_HOST, "thread_name", "coordinator"),
        metadata_event(PID_VIRT, "thread_name", "coordinator"),
    ];
    events.extend(evs.iter().map(|e| e.to_json(tel.backend())));

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("backend", Json::str(tel.backend())),
                ("sched", Json::str(tel.sched())),
            ]),
        ),
    ])
}

/// One-span-per-line JSONL stream (absent clocks serialize as `null`).
pub fn spans_jsonl(tel: &Telemetry) -> String {
    let mut out = String::new();
    for s in tel.spans() {
        let j = Json::obj(vec![
            ("phase", Json::str(s.phase.name())),
            ("round", Json::num(s.round as f64)),
            ("client", s.client.map(|c| Json::num(c as f64)).unwrap_or(Json::Null)),
            ("host_start_us", s.host.map(|(t, _)| Json::num(t as f64)).unwrap_or(Json::Null)),
            ("host_dur_us", s.host.map(|(_, d)| Json::num(d as f64)).unwrap_or(Json::Null)),
            ("virt_start_s", s.virt.map(|(a, _)| Json::num(a)).unwrap_or(Json::Null)),
            ("virt_end_s", s.virt.map(|(_, b)| Json::num(b)).unwrap_or(Json::Null)),
        ]);
        out.push_str(&j.to_string());
        out.push('\n');
    }
    out
}

fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// Write the Chrome trace to `path` (creating parent directories).
pub fn write_chrome_trace(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, chrome_trace_json(tel).to_string())?;
    Ok(())
}

/// Write the JSONL span stream to `path`.
pub fn write_spans_jsonl(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, spans_jsonl(tel))?;
    Ok(())
}

/// Write the metrics document (pretty-printed) to `path`.
pub fn write_metrics_json(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, tel.metrics_json().to_pretty())?;
    Ok(())
}

/// The JSONL sibling of a `--trace` path: `.json` → `.jsonl`, anything
/// else gets `.jsonl` appended.
pub fn jsonl_sibling(trace: &Path) -> PathBuf {
    if trace.extension().and_then(|e| e.to_str()) == Some("json") {
        trace.with_extension("jsonl")
    } else {
        let mut p = trace.as_os_str().to_owned();
        p.push(".jsonl");
        PathBuf::from(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Phase;

    fn traced() -> Telemetry {
        let tel = Telemetry::new("scalar", "semisync");
        // Round 0: coordinator host work + two client virt timelines.
        let sp = Telemetry::timer(Some(&tel)).unwrap();
        sp.end(Phase::BroadcastEncode, 0, None);
        tel.virt_span(Phase::ClientCompress, 0, Some(0), 0.0, 0.4);
        tel.virt_span(Phase::UplinkTransit, 0, Some(0), 0.4, 1.0);
        tel.virt_span(Phase::ClientCompress, 0, Some(1), 0.0, 0.2);
        tel.virt_span(Phase::UplinkTransit, 0, Some(1), 0.2, 2.0);
        let sp = Telemetry::timer(Some(&tel)).unwrap();
        sp.end(Phase::Fold, 0, None);
        tel
    }

    #[test]
    fn chrome_trace_has_both_tracks_and_parses() {
        let tel = traced();
        let doc = chrome_trace_json(&tel);
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let events = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: Vec<usize> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .map(|e| e.get("pid").unwrap().as_usize().unwrap())
            .collect();
        assert!(pids.contains(&1), "host track present");
        assert!(pids.contains(&2), "virtual track present");
    }

    #[test]
    fn chrome_trace_ts_monotonic_per_track() {
        let tel = traced();
        let doc = chrome_trace_json(&tel);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for e in events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")) {
            let key = (
                e.get("pid").unwrap().as_usize().unwrap(),
                e.get("tid").unwrap().as_usize().unwrap(),
            );
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            if let Some(prev) = last.get(&key) {
                assert!(ts >= *prev, "ts regressed on track {key:?}");
            }
            last.insert(key, ts);
        }
    }

    #[test]
    fn jsonl_one_valid_object_per_line() {
        let tel = traced();
        let stream = spans_jsonl(&tel);
        let lines: Vec<&str> = stream.lines().collect();
        assert_eq!(lines.len(), tel.span_count());
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("phase").unwrap().as_str().is_some());
        }
    }

    #[test]
    fn jsonl_sibling_swaps_extension() {
        assert_eq!(jsonl_sibling(Path::new("out/run.trace.json")), Path::new("out/run.trace.jsonl"));
        assert_eq!(jsonl_sibling(Path::new("out/trace")), Path::new("out/trace.jsonl"));
    }
}
