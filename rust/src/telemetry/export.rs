//! Trace and metrics exporters.
//!
//! Three artifacts per traced run:
//!
//! * **Chrome `trace_event` JSON** ([`write_chrome_trace`]) — open in
//!   `chrome://tracing` or <https://ui.perfetto.dev>. Two processes:
//!   pid 1 is host wall-time (real encode/decode/fold cost), pid 2 is
//!   the scheduler's virtual clock (simulated compute + transit, 1 s of
//!   virtual time rendered as 1 s of trace time). On both, tid 0 is the
//!   coordinator and tid `c+1` is client `c`.
//! * **JSONL span stream** ([`write_spans_jsonl`]) — one span object per
//!   line, for ad-hoc `jq`/pandas processing.
//! * **Metrics JSON** ([`write_metrics_json`]) — the
//!   [`Telemetry::metrics_json`] document: run identity, run-level
//!   totals, and one [`super::RoundSnapshot`] per round.
//!
//! A diag-armed run (`--diag`) adds a fourth artifact: the
//! [`write_diag_csv`] table of per-round, per-layer estimator rows, and
//! a `"diag"` section ([`diag_json`]) inside the metrics document when
//! both exports are armed ([`write_metrics_json_with_diag`]).
//!
//! Validated by `scripts/check_trace.py` (schema, per-track monotonic
//! timestamps, span nesting) and `scripts/check_diag.py` (estimator
//! ranges, monotone byte totals) in the CI trace-smoke and diag-smoke
//! jobs.

use std::path::{Path, PathBuf};

use super::{Span, Telemetry};
use crate::config::Json;
use crate::diag::{DiagRow, DiagState};

/// Host wall-time track.
const PID_HOST: u64 = 1;
/// Virtual-clock track.
const PID_VIRT: u64 = 2;

fn metadata_event(pid: u64, kind: &str, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("name", Json::str(kind)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

struct Ev {
    pid: u64,
    tid: u64,
    ts: f64,
    dur: f64,
    name: &'static str,
    cat: &'static str,
    round: u64,
    client: Option<u32>,
}

impl Ev {
    fn to_json(&self, backend: &str) -> Json {
        let mut args = vec![
            ("round", Json::num(self.round as f64)),
            ("backend", Json::str(backend)),
        ];
        if let Some(c) = self.client {
            args.push(("client", Json::num(c as f64)));
        }
        Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(self.pid as f64)),
            ("tid", Json::num(self.tid as f64)),
            ("ts", Json::num(self.ts)),
            ("dur", Json::num(self.dur)),
            ("name", Json::str(self.name)),
            ("cat", Json::str(self.cat)),
            ("args", Json::obj(args)),
        ])
    }
}

/// Build the Chrome `trace_event` document for everything recorded so far.
pub fn chrome_trace_json(tel: &Telemetry) -> Json {
    let mut evs: Vec<Ev> = Vec::new();
    for s in tel.spans() {
        let Span { phase, round, client, host, virt } = s;
        let tid = client.map(|c| c as u64 + 1).unwrap_or(0);
        if let Some((start_us, dur_us)) = host {
            evs.push(Ev {
                pid: PID_HOST,
                tid,
                ts: start_us as f64,
                dur: dur_us as f64,
                name: phase.name(),
                cat: "host",
                round,
                client,
            });
        }
        if let Some((start_s, end_s)) = virt {
            evs.push(Ev {
                pid: PID_VIRT,
                tid,
                ts: start_s * 1e6,
                dur: (end_s - start_s) * 1e6,
                name: phase.name(),
                cat: "virtual",
                round,
                client,
            });
        }
    }
    // Per-track timestamp order; longer span first on ties so containment
    // nests (parents open before children at the same instant).
    evs.sort_by(|a, b| {
        (a.pid, a.tid)
            .cmp(&(b.pid, b.tid))
            .then(a.ts.total_cmp(&b.ts))
            .then(b.dur.total_cmp(&a.dur))
    });

    let mut events = vec![
        metadata_event(PID_HOST, "process_name", "host wall-time"),
        metadata_event(PID_VIRT, "process_name", "virtual clock"),
        metadata_event(PID_HOST, "thread_name", "coordinator"),
        metadata_event(PID_VIRT, "thread_name", "coordinator"),
    ];
    events.extend(evs.iter().map(|e| e.to_json(tel.backend())));

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("backend", Json::str(tel.backend())),
                ("sched", Json::str(tel.sched())),
            ]),
        ),
    ])
}

/// One-span-per-line JSONL stream (absent clocks serialize as `null`).
pub fn spans_jsonl(tel: &Telemetry) -> String {
    let mut out = String::new();
    for s in tel.spans() {
        let j = Json::obj(vec![
            ("phase", Json::str(s.phase.name())),
            ("round", Json::num(s.round as f64)),
            ("client", s.client.map(|c| Json::num(c as f64)).unwrap_or(Json::Null)),
            ("host_start_us", s.host.map(|(t, _)| Json::num(t as f64)).unwrap_or(Json::Null)),
            ("host_dur_us", s.host.map(|(_, d)| Json::num(d as f64)).unwrap_or(Json::Null)),
            ("virt_start_s", s.virt.map(|(a, _)| Json::num(a)).unwrap_or(Json::Null)),
            ("virt_end_s", s.virt.map(|(_, b)| Json::num(b)).unwrap_or(Json::Null)),
        ]);
        out.push_str(&j.to_string());
        out.push('\n');
    }
    out
}

fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// Write the Chrome trace to `path` (creating parent directories).
pub fn write_chrome_trace(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, chrome_trace_json(tel).to_string())?;
    Ok(())
}

/// Write the JSONL span stream to `path`.
pub fn write_spans_jsonl(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, spans_jsonl(tel))?;
    Ok(())
}

/// Write the metrics document (pretty-printed) to `path`.
pub fn write_metrics_json(tel: &Telemetry, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, tel.metrics_json().to_pretty())?;
    Ok(())
}

/// `diag.csv` header, in the order [`diag_csv`] emits the fields.
/// Absent metrics serialize as empty cells, never as fake zeros.
pub const DIAG_CSV_HEADER: &str = "round,layer,drift_mean_angle,drift_max_angle,\
drift_chordal,churn_dr,energy_coverage,cosine,nrmse,stable_rank,\
bytes_per_unit_energy,cum_uplink_bytes,loss_drop,bytes_per_loss";

fn cell_f(v: Option<f64>) -> String {
    v.map(|x| x.to_string()).unwrap_or_default()
}

fn cell_u(v: Option<u64>) -> String {
    v.map(|x| x.to_string()).unwrap_or_default()
}

/// Render the diagnostics table as CSV (header + one line per
/// [`DiagRow`], layer rows before each round's `*` aggregate).
pub fn diag_csv(state: &DiagState) -> String {
    let mut out = String::from(DIAG_CSV_HEADER);
    out.push('\n');
    for r in &state.rows {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            r.round,
            r.layer,
            cell_f(r.drift_mean_angle),
            cell_f(r.drift_max_angle),
            cell_f(r.drift_chordal),
            cell_u(r.churn_dr),
            cell_f(r.energy_coverage),
            cell_f(r.cosine),
            cell_f(r.nrmse),
            cell_f(r.stable_rank),
            cell_f(r.bytes_per_unit_energy),
            cell_u(r.cum_uplink_bytes),
            cell_f(r.loss_drop),
            cell_f(r.bytes_per_loss),
        ));
    }
    out
}

/// Write the diagnostics table to `path` (creating parent directories).
pub fn write_diag_csv(state: &DiagState, path: &Path) -> crate::Result<()> {
    ensure_parent(path)?;
    std::fs::write(path, diag_csv(state))?;
    Ok(())
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::num).unwrap_or(Json::Null)
}

fn diag_row_json(r: &DiagRow) -> Json {
    Json::obj(vec![
        ("round", Json::num(r.round as f64)),
        ("drift_mean_angle", opt_num(r.drift_mean_angle)),
        ("drift_max_angle", opt_num(r.drift_max_angle)),
        ("drift_chordal", opt_num(r.drift_chordal)),
        ("churn_dr", opt_num(r.churn_dr.map(|c| c as f64))),
        ("energy_coverage", opt_num(r.energy_coverage)),
        ("cosine", opt_num(r.cosine)),
        ("nrmse", opt_num(r.nrmse)),
        ("stable_rank", opt_num(r.stable_rank)),
        ("bytes_per_unit_energy", opt_num(r.bytes_per_unit_energy)),
        ("cum_uplink_bytes", opt_num(r.cum_uplink_bytes.map(|b| b as f64))),
        ("loss_drop", opt_num(r.loss_drop)),
        ("bytes_per_loss", opt_num(r.bytes_per_loss)),
    ])
}

/// The metrics-JSON `"diag"` section: the sampled clients, the layer
/// table, the run-level adjacent-cosine means, and the per-round
/// aggregate (`layer == "*"`) rows. Per-layer detail stays in the CSV.
pub fn diag_json(state: &DiagState) -> Json {
    Json::obj(vec![
        (
            "sample",
            Json::Arr(state.sample.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        (
            "layers",
            Json::Arr(state.layer_names.iter().map(|n| Json::str(n)).collect()),
        ),
        (
            "run_adjacent_cosine",
            Json::Arr(state.adjacent_mean_per_layer().into_iter().map(Json::num).collect()),
        ),
        ("adjacent_pairs", Json::num(state.run_adj_pairs as f64)),
        (
            "rounds",
            Json::Arr(
                state.rows.iter().filter(|r| r.layer == "*").map(diag_row_json).collect(),
            ),
        ),
    ])
}

/// Write the metrics document with the diagnostics section attached
/// (falls back to the plain document when `diag` is `None`).
pub fn write_metrics_json_with_diag(
    tel: &Telemetry,
    diag: Option<&DiagState>,
    path: &Path,
) -> crate::Result<()> {
    ensure_parent(path)?;
    let mut doc = tel.metrics_json();
    if let (Some(state), Json::Obj(fields)) = (diag, &mut doc) {
        fields.insert("diag".to_string(), diag_json(state));
    }
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

/// The JSONL sibling of a `--trace` path: `.json` → `.jsonl`, anything
/// else gets `.jsonl` appended.
pub fn jsonl_sibling(trace: &Path) -> PathBuf {
    if trace.extension().and_then(|e| e.to_str()) == Some("json") {
        trace.with_extension("jsonl")
    } else {
        let mut p = trace.as_os_str().to_owned();
        p.push(".jsonl");
        PathBuf::from(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Phase;

    fn traced() -> Telemetry {
        let tel = Telemetry::new("scalar", "semisync");
        // Round 0: coordinator host work + two client virt timelines.
        let sp = Telemetry::timer(Some(&tel)).unwrap();
        sp.end(Phase::BroadcastEncode, 0, None);
        tel.virt_span(Phase::ClientCompress, 0, Some(0), 0.0, 0.4);
        tel.virt_span(Phase::UplinkTransit, 0, Some(0), 0.4, 1.0);
        tel.virt_span(Phase::ClientCompress, 0, Some(1), 0.0, 0.2);
        tel.virt_span(Phase::UplinkTransit, 0, Some(1), 0.2, 2.0);
        let sp = Telemetry::timer(Some(&tel)).unwrap();
        sp.end(Phase::Fold, 0, None);
        tel
    }

    #[test]
    fn chrome_trace_has_both_tracks_and_parses() {
        let tel = traced();
        let doc = chrome_trace_json(&tel);
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        let events = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: Vec<usize> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .map(|e| e.get("pid").unwrap().as_usize().unwrap())
            .collect();
        assert!(pids.contains(&1), "host track present");
        assert!(pids.contains(&2), "virtual track present");
    }

    #[test]
    fn chrome_trace_ts_monotonic_per_track() {
        let tel = traced();
        let doc = chrome_trace_json(&tel);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for e in events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")) {
            let key = (
                e.get("pid").unwrap().as_usize().unwrap(),
                e.get("tid").unwrap().as_usize().unwrap(),
            );
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            if let Some(prev) = last.get(&key) {
                assert!(ts >= *prev, "ts regressed on track {key:?}");
            }
            last.insert(key, ts);
        }
    }

    #[test]
    fn jsonl_one_valid_object_per_line() {
        let tel = traced();
        let stream = spans_jsonl(&tel);
        let lines: Vec<&str> = stream.lines().collect();
        assert_eq!(lines.len(), tel.span_count());
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("phase").unwrap().as_str().is_some());
        }
    }

    fn diag_state() -> DiagState {
        DiagState {
            rows: vec![
                DiagRow {
                    round: 0,
                    layer: "conv1.kernel".into(),
                    nrmse: Some(0.25),
                    cosine: Some(0.9),
                    ..Default::default()
                },
                DiagRow {
                    round: 0,
                    layer: "*".into(),
                    nrmse: Some(0.25),
                    cum_uplink_bytes: Some(1024),
                    bytes_per_loss: Some(2048.0),
                    ..Default::default()
                },
            ],
            sample: vec![0, 3],
            layer_names: vec!["conv1.kernel".into()],
            run_adj_sum: vec![1.8],
            run_adj_pairs: 2,
        }
    }

    #[test]
    fn diag_csv_has_header_and_empty_cells() {
        let csv = diag_csv(&diag_state());
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert_eq!(header, DIAG_CSV_HEADER);
        assert_eq!(header.split(',').count(), 14);
        let layer_row = lines.next().unwrap();
        assert!(layer_row.starts_with("0,conv1.kernel,"));
        assert_eq!(layer_row.split(',').count(), 14, "absent metrics stay as empty cells");
        let agg = lines.next().unwrap();
        assert!(agg.contains(",1024,"), "aggregate carries cumulative bytes");
        assert!(lines.next().is_none());
    }

    #[test]
    fn diag_json_carries_aggregates_and_parses() {
        let j = diag_json(&diag_state());
        let reparsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(reparsed.get("sample").unwrap().as_arr().unwrap().len(), 2);
        let cos = reparsed.get("run_adjacent_cosine").unwrap().as_arr().unwrap();
        assert!((cos[0].as_f64().unwrap() - 0.9).abs() < 1e-12);
        let rounds = reparsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1, "aggregate rows only");
        assert!(rounds[0].get("nrmse").unwrap().as_f64().is_some());
    }

    #[test]
    fn metrics_json_with_diag_gains_the_section() {
        let tel = traced();
        let dir = std::env::temp_dir().join("gradestc_diag_export_test");
        let path = dir.join("metrics.json");
        write_metrics_json_with_diag(&tel, Some(&diag_state()), &path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(doc.get("diag").is_some(), "diag section attached");
        assert_eq!(doc.get("backend").unwrap().as_str(), Some("scalar"));
        write_metrics_json_with_diag(&tel, None, &path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(doc.get("diag").is_none(), "plain document without state");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_sibling_swaps_extension() {
        assert_eq!(jsonl_sibling(Path::new("out/run.trace.json")), Path::new("out/run.trace.jsonl"));
        assert_eq!(jsonl_sibling(Path::new("out/trace")), Path::new("out/trace.jsonl"));
    }
}
