//! Counters, gauges, and histograms, snapshotted per round.
//!
//! All mutation goes through one `Mutex` with tiny critical sections
//! (integer adds, map inserts). Counter adds are commutative, so worker
//! threads bumping the same key in any order produce the same totals —
//! the registry observes the run without participating in it. Per-phase
//! *virtual*-time totals are only ever added from the coordinator/event
//! loop thread in deterministic order, so their `f64` sums are
//! bit-reproducible too; host-time totals are wall-clock measurements and
//! inherently jittery.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::config::Json;

/// Bucket upper bounds for the staleness histogram (versions behind).
pub const STALENESS_BOUNDS: [f64; 7] = [0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0];

/// Fixed-bound histogram (`counts.len() == bounds.len() + 1`; the last
/// bucket is the overflow bucket).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
}

impl Histogram {
    /// New histogram with ascending bucket upper bounds.
    pub fn new(bounds: &[f64]) -> Self {
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, n: 0 }
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    /// Total observations.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Sum of observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Per-bucket counts (last entry = overflow bucket).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Bucket upper bounds.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.sum = 0.0;
        self.n = 0;
    }

    /// JSON form: `{"bounds": [...], "counts": [...], "sum": x, "n": n}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bounds", Json::Arr(self.bounds.iter().map(|b| Json::num(*b)).collect())),
            ("counts", Json::Arr(self.counts.iter().map(|c| Json::num(*c as f64)).collect())),
            ("sum", Json::num(self.sum)),
            ("n", Json::num(self.n as f64)),
        ])
    }
}

/// One round's worth of metrics, frozen at round end.
///
/// Rides on [`crate::metrics::RoundRecord::ext`] (behind an `Arc` so the
/// record stays cheap to clone) and in the end-of-run metrics JSON.
#[derive(Clone, Debug)]
pub struct RoundSnapshot {
    /// Round index (async: apply index).
    pub round: u64,
    /// Monotonic counters scoped to this round (bytes by payload variant,
    /// straggler/dropout counts, transport frame deltas, ...).
    pub counters: BTreeMap<&'static str, u64>,
    /// Point-in-time gauges (basis-pool entries/bytes, slot occupancy, ...).
    pub gauges: BTreeMap<&'static str, f64>,
    /// Host wall-time spent per phase this round, microseconds.
    pub phase_host_us: BTreeMap<&'static str, u64>,
    /// Virtual-clock time accrued per phase this round, seconds.
    pub phase_virt_s: BTreeMap<&'static str, f64>,
    /// Staleness of updates folded this round (versions behind).
    pub staleness: Histogram,
}

impl RoundSnapshot {
    /// JSON form (one element of the metrics file's `rounds` array).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("counters", map_u64_json(&self.counters)),
            ("gauges", map_f64_json(&self.gauges)),
            ("phase_host_us", map_u64_json(&self.phase_host_us)),
            ("phase_virt_s", map_f64_json(&self.phase_virt_s)),
            ("staleness", self.staleness.to_json()),
        ])
    }
}

fn map_u64_json(m: &BTreeMap<&'static str, u64>) -> Json {
    Json::obj(m.iter().map(|(k, v)| (*k, Json::num(*v as f64))).collect())
}

fn map_f64_json(m: &BTreeMap<&'static str, f64>) -> Json {
    Json::obj(m.iter().map(|(k, v)| (*k, Json::num(*v))).collect())
}

struct Inner {
    run_counters: BTreeMap<&'static str, u64>,
    round_counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    run_phase_host_us: BTreeMap<&'static str, u64>,
    round_phase_host_us: BTreeMap<&'static str, u64>,
    run_phase_virt_s: BTreeMap<&'static str, f64>,
    round_phase_virt_s: BTreeMap<&'static str, f64>,
    run_staleness: Histogram,
    round_staleness: Histogram,
    rounds: Vec<Arc<RoundSnapshot>>,
}

/// The metrics store behind [`super::Telemetry`].
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        MetricsRegistry {
            inner: Mutex::new(Inner {
                run_counters: BTreeMap::new(),
                round_counters: BTreeMap::new(),
                gauges: BTreeMap::new(),
                run_phase_host_us: BTreeMap::new(),
                round_phase_host_us: BTreeMap::new(),
                run_phase_virt_s: BTreeMap::new(),
                round_phase_virt_s: BTreeMap::new(),
                run_staleness: Histogram::new(&STALENESS_BOUNDS),
                round_staleness: Histogram::new(&STALENESS_BOUNDS),
                rounds: Vec::new(),
            }),
        }
    }

    /// Add `delta` to a counter (round- and run-scoped).
    pub fn count(&self, key: &'static str, delta: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.run_counters.entry(key).or_insert(0) += delta;
        *g.round_counters.entry(key).or_insert(0) += delta;
    }

    /// Set a gauge (last write before the round snapshot wins).
    pub fn gauge(&self, key: &'static str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(key, value);
    }

    /// Record one staleness observation (versions behind at fold time).
    pub fn observe_staleness(&self, tau: f64) {
        let mut g = self.inner.lock().unwrap();
        g.run_staleness.observe(tau);
        g.round_staleness.observe(tau);
    }

    /// Accrue host wall-time against a phase.
    pub fn phase_host(&self, phase: &'static str, us: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.run_phase_host_us.entry(phase).or_insert(0) += us;
        *g.round_phase_host_us.entry(phase).or_insert(0) += us;
    }

    /// Accrue virtual-clock time against a phase.
    pub fn phase_virt(&self, phase: &'static str, s: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.run_phase_virt_s.entry(phase).or_insert(0.0) += s;
        *g.round_phase_virt_s.entry(phase).or_insert(0.0) += s;
    }

    /// Freeze the round-scoped state into a [`RoundSnapshot`], append it to
    /// the run's round list, and reset the round accumulators.
    pub fn snapshot_round(&self, round: u64) -> Arc<RoundSnapshot> {
        let mut g = self.inner.lock().unwrap();
        let snap = Arc::new(RoundSnapshot {
            round,
            counters: std::mem::take(&mut g.round_counters),
            gauges: g.gauges.clone(),
            phase_host_us: std::mem::take(&mut g.round_phase_host_us),
            phase_virt_s: std::mem::take(&mut g.round_phase_virt_s),
            staleness: g.round_staleness.clone(),
        });
        g.round_staleness.reset();
        g.rounds.push(Arc::clone(&snap));
        snap
    }

    /// All round snapshots taken so far.
    pub fn rounds(&self) -> Vec<Arc<RoundSnapshot>> {
        self.inner.lock().unwrap().rounds.clone()
    }

    /// Current value of a run-scoped counter (0 when never bumped).
    pub fn run_counter(&self, key: &'static str) -> u64 {
        self.inner.lock().unwrap().run_counters.get(key).copied().unwrap_or(0)
    }

    /// The run-level staleness histogram.
    pub fn run_staleness(&self) -> Histogram {
        self.inner.lock().unwrap().run_staleness.clone()
    }

    /// JSON body: `{"run": {...}, "rounds": [...]}` fields as a pair list
    /// the caller can extend with run identity (backend, sched).
    pub fn to_json_fields(&self) -> Vec<(&'static str, Json)> {
        let g = self.inner.lock().unwrap();
        let run = Json::obj(vec![
            ("counters", map_u64_json(&g.run_counters)),
            ("phase_host_us", map_u64_json(&g.run_phase_host_us)),
            ("phase_virt_s", map_f64_json(&g.run_phase_virt_s)),
            ("staleness", g.run_staleness.to_json()),
        ]);
        let rounds = Json::Arr(g.rounds.iter().map(|r| r.to_json()).collect());
        vec![("run", run), ("rounds", rounds)]
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[0.0, 1.0, 4.0]);
        for v in [0.0, 0.0, 1.0, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        assert_eq!(h.n(), 5);
        assert!((h.sum() - 104.0).abs() < 1e-9);
    }

    #[test]
    fn counters_scope_to_rounds_and_run() {
        let r = MetricsRegistry::new();
        r.count("bytes.sparse", 10);
        let s0 = r.snapshot_round(0);
        assert_eq!(s0.counters["bytes.sparse"], 10);
        r.count("bytes.sparse", 5);
        let s1 = r.snapshot_round(1);
        assert_eq!(s1.counters["bytes.sparse"], 5);
        assert_eq!(r.run_counter("bytes.sparse"), 15);
        assert_eq!(r.rounds().len(), 2);
    }

    #[test]
    fn staleness_resets_per_round_but_accumulates_per_run() {
        let r = MetricsRegistry::new();
        r.observe_staleness(0.0);
        r.observe_staleness(2.0);
        let s0 = r.snapshot_round(0);
        assert_eq!(s0.staleness.n(), 2);
        r.observe_staleness(1.0);
        let s1 = r.snapshot_round(1);
        assert_eq!(s1.staleness.n(), 1);
        assert_eq!(r.run_staleness().n(), 3);
    }

    #[test]
    fn counter_adds_commute() {
        let a = MetricsRegistry::new();
        let b = MetricsRegistry::new();
        a.count("x", 3);
        a.count("y", 1);
        a.count("x", 4);
        b.count("x", 4);
        b.count("x", 3);
        b.count("y", 1);
        assert_eq!(a.run_counter("x"), b.run_counter("x"));
        assert_eq!(a.run_counter("y"), b.run_counter("y"));
    }

    #[test]
    fn json_fields_shape() {
        let r = MetricsRegistry::new();
        r.count("bytes.basis", 7);
        r.phase_virt("uplink_transit", 1.5);
        r.snapshot_round(0);
        let j = Json::Obj(
            r.to_json_fields().into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        );
        let run = j.get("run").unwrap();
        assert_eq!(run.get("counters").unwrap().get("bytes.basis").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("rounds").unwrap().as_arr().unwrap().len(), 1);
        // Round-trips through the strict parser.
        assert!(Json::parse(&j.to_pretty()).is_ok());
    }
}
