//! Telemetry plane (plane 7): span tracing, metrics, and run probes.
//!
//! Everything here *observes* a run without participating in it. The
//! plane has three surfaces:
//!
//! * [`Telemetry`] — the per-run facade: a sharded span [`tracer`] keyed
//!   by both host wall-time and the scheduler's virtual clock, plus a
//!   [`MetricsRegistry`] of counters/gauges/histograms snapshotted per
//!   round into [`RoundSnapshot`]s (riding on
//!   [`crate::metrics::RoundRecord::ext`]).
//! * [`export`] — Chrome `trace_event` JSON (two tracks: pid 1 = host
//!   wall-time, pid 2 = virtual clock; load in `chrome://tracing` or
//!   Perfetto), a JSONL span stream, and the end-of-run metrics JSON.
//! * [`Observer`] — the streaming per-arrival probe API called from all
//!   three schedulers (the successor of the sync-only round hook).
//!
//! The diagnostics plane rides on the third surface: [`DiagProbe`] is an
//! `Observer` that drives the [`crate::diag`] estimators and publishes
//! `diag.*` gauges and [`Phase::Diag`] spans into the first two.
//!
//! **Disabled-path cost contract:** a `Simulation` without
//! `enable_telemetry()` holds `None` — no span buffer, no registry, no
//! transport wrapper is ever allocated, and every instrumentation site is
//! one `Option` test. With telemetry *enabled*, recording only appends
//! tag-sharded data behind short locks and adds commutative counters, so
//! results stay bit-identical at any worker count — locked in by
//! `rust/tests/telemetry.rs`.

mod diag;
pub mod export;
mod observer;
mod registry;
mod tracer;

pub use diag::DiagProbe;
pub use observer::{ApplyEvent, ArrivalEvent, DispatchEvent, Observer};
pub use registry::{Histogram, MetricsRegistry, RoundSnapshot, STALENESS_BOUNDS};
pub use tracer::{Phase, Span};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::compress::Payload;
use crate::config::Json;
use crate::net::transport::TransportCounters;

/// Per-run telemetry facade. Created by
/// [`crate::coordinator::Simulation::enable_telemetry`]; all recording
/// sites take `Option<&Telemetry>` and are no-ops on `None`.
pub struct Telemetry {
    epoch: Instant,
    backend: &'static str,
    sched: &'static str,
    tracer: tracer::Tracer,
    metrics: MetricsRegistry,
    transport: Arc<TransportCounters>,
    prev_transport: Mutex<[u64; 4]>,
}

/// In-flight host-time span, started via [`Telemetry::timer`].
pub struct SpanTimer<'a> {
    tel: &'a Telemetry,
    start_us: u64,
}

impl<'a> SpanTimer<'a> {
    /// Close the span and record it.
    pub fn end(self, phase: Phase, round: u64, client: Option<u32>) {
        self.tel.host_span(phase, round, client, self.start_us);
    }
}

impl Telemetry {
    /// Fresh telemetry for one run, tagged with the run's backend and
    /// scheduler names (they ride into every export).
    pub fn new(backend: &'static str, sched: &'static str) -> Self {
        Telemetry {
            epoch: Instant::now(),
            backend,
            sched,
            tracer: tracer::Tracer::new(),
            metrics: MetricsRegistry::new(),
            transport: Arc::new(TransportCounters::new()),
            prev_transport: Mutex::new([0; 4]),
        }
    }

    /// Backend name this run executes on.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Scheduler name this run executes under.
    pub fn sched(&self) -> &'static str {
        self.sched
    }

    /// Microseconds since this run's telemetry epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Start a host-time span timer; `None` in, `None` out — the disabled
    /// path is a single branch with no clock read.
    pub fn timer(tel: Option<&Telemetry>) -> Option<SpanTimer<'_>> {
        tel.map(|t| SpanTimer { tel: t, start_us: t.now_us() })
    }

    /// Record a host wall-time span that started at `start_us`.
    pub fn host_span(&self, phase: Phase, round: u64, client: Option<u32>, start_us: u64) {
        let dur = self.now_us().saturating_sub(start_us);
        self.metrics.phase_host(phase.name(), dur);
        self.tracer.record(Span { phase, round, client, host: Some((start_us, dur)), virt: None });
    }

    /// Record a virtual-clock span `[start_s, end_s]`.
    pub fn virt_span(&self, phase: Phase, round: u64, client: Option<u32>, start_s: f64, end_s: f64) {
        let dur = (end_s - start_s).max(0.0);
        self.metrics.phase_virt(phase.name(), dur);
        self.tracer
            .record(Span { phase, round, client, host: None, virt: Some((start_s, start_s + dur)) });
    }

    /// Add `delta` to a counter.
    pub fn count(&self, key: &'static str, delta: u64) {
        self.metrics.count(key, delta);
    }

    /// Set a gauge.
    pub fn gauge(&self, key: &'static str, value: f64) {
        self.metrics.gauge(key, value);
    }

    /// Record the staleness (versions/rounds behind) of a folded update.
    pub fn observe_staleness(&self, tau: u64) {
        self.metrics.observe_staleness(tau as f64);
    }

    /// Charge one decoded upload's payloads to the per-variant byte
    /// counters (`bytes.raw`, `bytes.sparse`, `bytes.quantized`,
    /// `bytes.signs`, `bytes.basis`, `bytes.svd`).
    pub fn count_payloads(&self, payloads: &[Payload]) {
        for p in payloads {
            let key = match p {
                Payload::Raw(_) => "bytes.raw",
                Payload::Sparse { .. } => "bytes.sparse",
                Payload::Quantized { .. } => "bytes.quantized",
                Payload::Signs { .. } => "bytes.signs",
                Payload::Basis { .. } => "bytes.basis",
                Payload::SvdCoeffs { .. } => "bytes.svd",
            };
            self.metrics.count(key, p.wire_bytes());
        }
    }

    /// The transport counters the [`crate::net::transport::Instrumented`]
    /// wrapper feeds.
    pub fn transport_counters(&self) -> Arc<TransportCounters> {
        Arc::clone(&self.transport)
    }

    /// Freeze this round's metrics (folding in transport-frame deltas
    /// since the previous snapshot) and return the snapshot for
    /// [`crate::metrics::RoundRecord::ext`].
    pub fn snapshot_round(&self, round: u64) -> Arc<RoundSnapshot> {
        let cur = self.transport.snapshot();
        let mut prev = self.prev_transport.lock().unwrap();
        const KEYS: [&str; 4] = [
            "transport.broadcast_frames",
            "transport.broadcast_bytes",
            "transport.upload_frames",
            "transport.upload_bytes",
        ];
        for (i, key) in KEYS.iter().enumerate() {
            let delta = cur[i].saturating_sub(prev[i]);
            if delta > 0 {
                self.metrics.count(key, delta);
            }
        }
        *prev = cur;
        drop(prev);
        self.metrics.snapshot_round(round)
    }

    /// The metrics store (counters/gauges/histograms/round snapshots).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// All spans recorded so far, deterministically ordered.
    pub fn spans(&self) -> Vec<Span> {
        self.tracer.snapshot()
    }

    /// Number of spans recorded so far.
    pub fn span_count(&self) -> usize {
        self.tracer.len()
    }

    /// End-of-run metrics document:
    /// `{"backend", "sched", "run": {...}, "rounds": [...]}`.
    pub fn metrics_json(&self) -> Json {
        let mut fields =
            vec![("backend", Json::str(self.backend)), ("sched", Json::str(self.sched))];
        fields.extend(self.metrics.to_json_fields());
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_none_when_disabled() {
        assert!(Telemetry::timer(None).is_none());
    }

    #[test]
    fn host_and_virt_spans_accrue_phase_totals() {
        let tel = Telemetry::new("scalar", "sync");
        let sp = Telemetry::timer(Some(&tel)).unwrap();
        sp.end(Phase::Fold, 0, None);
        tel.virt_span(Phase::UplinkTransit, 0, Some(3), 1.0, 2.5);
        assert_eq!(tel.span_count(), 2);
        let snap = tel.snapshot_round(0);
        assert!((snap.phase_virt_s["uplink_transit"] - 1.5).abs() < 1e-12);
        assert!(snap.phase_host_us.contains_key("fold"));
    }

    #[test]
    fn payload_byte_counters_match_wire_bytes() {
        let tel = Telemetry::new("blocked", "async");
        let p = Payload::Sparse { indices: vec![1, 2], values: vec![0.5, -0.5], len: 16 };
        let want = p.wire_bytes();
        tel.count_payloads(&[p]);
        assert_eq!(tel.metrics().run_counter("bytes.sparse"), want);
    }

    #[test]
    fn transport_deltas_fold_into_round_counters() {
        let tel = Telemetry::new("scalar", "semisync");
        let tc = tel.transport_counters();
        tc.add_broadcast(100);
        tc.add_upload(40);
        let s0 = tel.snapshot_round(0);
        assert_eq!(s0.counters["transport.broadcast_bytes"], 100);
        assert_eq!(s0.counters["transport.upload_frames"], 1);
        tc.add_upload(60);
        let s1 = tel.snapshot_round(1);
        assert_eq!(s1.counters["transport.upload_bytes"], 60);
        assert!(!s1.counters.contains_key("transport.broadcast_bytes"));
    }

    #[test]
    fn metrics_json_parses_and_carries_identity() {
        let tel = Telemetry::new("blocked", "async");
        tel.count("dropouts", 2);
        tel.observe_staleness(3);
        tel.snapshot_round(0);
        let j = tel.metrics_json();
        assert_eq!(j.get("backend").unwrap().as_str(), Some("blocked"));
        assert_eq!(j.get("sched").unwrap().as_str(), Some("async"));
        let reparsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(
            reparsed.get("rounds").unwrap().as_arr().unwrap().len(),
            1,
            "one round snapshot"
        );
    }
}
