//! [`DiagProbe`]: the diagnostics plane's [`Observer`].
//!
//! One probe drives all four [`crate::diag`] estimators from the
//! streaming observer callbacks, so the same diagnostics flow from the
//! sync, semi-sync, and async schedulers without scheduler-specific
//! code:
//!
//! * `on_arrival` — for sampled clients only: fidelity per layer,
//!   adjacent-arrival cosine on the densified update, and (for the
//!   reference client, `sample[0]`) subspace drift on any low-rank
//!   basis the update carries.
//! * `on_apply` — flushes the round's estimator means into `diag.*`
//!   gauges; `on_apply` runs *before* the round snapshot is frozen, so
//!   the gauges land in the same round's
//!   [`RoundSnapshot`](crate::telemetry::RoundSnapshot).
//! * `on_round` — folds the finished record into the comms-efficiency
//!   tracker, appends the round's per-layer and aggregate
//!   [`DiagRow`]s to the shared [`DiagState`], and records one
//!   [`Phase::Diag`] host span.
//!
//! The bytes-per-loss gauge is the one value only computable *after*
//! the record exists, so it is set in `on_round` and appears in the
//! *next* round's snapshot; `diag.csv` rows (built in `on_round`) carry
//! it for the correct round.
//!
//! Determinism: the probe never touches a simulation RNG stream (the
//! client sample is drawn at construction on the dedicated diag
//! stream), never mutates anything it observes, and densifies borrowed
//! updates into its own buffers — diag-on, diag-off, and any
//! `--workers` value produce bit-identical records (`rust/tests/diag.rs`).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::compress::LayerUpdate;
use crate::config::ExperimentConfig;
use crate::diag::{
    sample_clients, CommsEfficiency, DiagConfig, DiagRow, DiagState, DriftSample, Fidelity,
    StreamingCosine, SubspaceDrift,
};
use crate::metrics::RoundRecord;
use crate::telemetry::{ApplyEvent, ArrivalEvent, Observer, Phase, Telemetry};

/// One layer's running sums for the round in flight.
#[derive(Clone, Debug, Default)]
struct LayerAcc {
    drift: Option<DriftSample>,
    cos_sum: f64,
    cos_n: u64,
    nrmse_sum: f64,
    nrmse_n: u64,
    cover_sum: f64,
    cover_n: u64,
    srank_sum: f64,
    srank_n: u64,
    bytes: u64,
    energy: f64,
}

fn mean(sum: f64, n: u64) -> Option<f64> {
    (n > 0).then(|| sum / n as f64)
}

/// Streaming diagnostics probe. Install with
/// [`Simulation::set_observer`](crate::coordinator::Simulation::set_observer);
/// read results from the shared [`DiagState`] after the run.
pub struct DiagProbe {
    state: Rc<RefCell<DiagState>>,
    tel: Option<Arc<Telemetry>>,
    drift: SubspaceDrift,
    stream: StreamingCosine,
    fidelity: Fidelity,
    comms: CommsEfficiency,
    /// Per-layer accumulators for the round in flight, indexed by tensor.
    acc: Vec<LayerAcc>,
}

impl DiagProbe {
    /// Probe for one run: the client sample is a pure function of
    /// `(cfg.seed, cfg.num_clients, dcfg.sample)` on the dedicated diag
    /// seed stream; estimator linalg runs on the run's backend.
    pub fn new(cfg: &ExperimentConfig, dcfg: DiagConfig) -> Self {
        let sample = sample_clients(cfg.seed, cfg.num_clients, dcfg.sample);
        let backend = cfg.backend.resolve();
        let state = DiagState { sample: sample.clone(), ..Default::default() };
        DiagProbe {
            state: Rc::new(RefCell::new(state)),
            tel: None,
            drift: SubspaceDrift::new(backend),
            stream: StreamingCosine::new(sample),
            fidelity: Fidelity::new(backend),
            comms: CommsEfficiency::new(),
            acc: Vec::new(),
        }
    }

    /// Attach the run's telemetry so the probe can publish `diag.*`
    /// gauges and [`Phase::Diag`] spans. Without it the probe still
    /// fills the [`DiagState`].
    pub fn with_telemetry(mut self, tel: Arc<Telemetry>) -> Self {
        self.tel = Some(tel);
        self
    }

    /// Shared handle to the accumulated diagnostics; clone before
    /// installing the probe.
    pub fn state(&self) -> Rc<RefCell<DiagState>> {
        Rc::clone(&self.state)
    }

    /// The reference client whose lane the drift estimator tracks.
    fn ref_client(&self) -> Option<usize> {
        self.stream.sample().first().copied()
    }

    fn layer_acc(&mut self, tensor: usize) -> &mut LayerAcc {
        if self.acc.len() <= tensor {
            self.acc.resize_with(tensor + 1, LayerAcc::default);
        }
        &mut self.acc[tensor]
    }

    /// Round aggregate across the per-layer accumulators, `None`-safe.
    fn aggregate(&self, round: usize) -> DiagRow {
        let mut row = DiagRow { round, layer: "*".into(), ..Default::default() };
        let drifts: Vec<&DriftSample> =
            self.acc.iter().filter_map(|l| l.drift.as_ref()).collect();
        if !drifts.is_empty() {
            row.drift_mean_angle =
                Some(drifts.iter().map(|d| d.mean_angle).sum::<f64>() / drifts.len() as f64);
            row.drift_max_angle =
                Some(drifts.iter().fold(0.0f64, |m, d| m.max(d.max_angle)));
            // Chordal distances add in quadrature across the layer-wise
            // direct sum of subspaces.
            row.drift_chordal =
                Some(drifts.iter().map(|d| d.chordal * d.chordal).sum::<f64>().sqrt());
            row.churn_dr = Some(drifts.iter().map(|d| d.churn).sum());
        }
        let fold = |f: fn(&LayerAcc) -> (f64, u64)| {
            let (s, n) = self
                .acc
                .iter()
                .map(f)
                .fold((0.0, 0), |(s, n), (ls, ln)| (s + ls, n + ln));
            mean(s, n)
        };
        row.cosine = fold(|l| (l.cos_sum, l.cos_n));
        row.nrmse = fold(|l| (l.nrmse_sum, l.nrmse_n));
        row.energy_coverage = fold(|l| (l.cover_sum, l.cover_n));
        row.stable_rank = fold(|l| (l.srank_sum, l.srank_n));
        let bytes: u64 = self.acc.iter().map(|l| l.bytes).sum();
        let energy: f64 = self.acc.iter().map(|l| l.energy).sum();
        if energy > 0.0 {
            row.bytes_per_unit_energy = Some(bytes as f64 / energy);
        }
        row
    }
}

impl Observer for DiagProbe {
    fn on_arrival(&mut self, ev: &ArrivalEvent) {
        {
            let mut st = self.state.borrow_mut();
            if st.layer_names.is_empty() {
                st.layer_names = ev.meta.layers.iter().map(|l| l.name.clone()).collect();
                st.run_adj_sum = vec![0.0; st.layer_names.len()];
            }
        }
        if !self.stream.is_sampled(ev.cid) {
            return;
        }
        let is_ref = self.ref_client() == Some(ev.cid);
        for (tensor, update) in ev.updates.iter().enumerate() {
            let s = self.fidelity.observe_layer(ev.cid, tensor, update);
            if is_ref {
                if let LayerUpdate::LowRank { basis, .. } = update {
                    if let Some(d) = self.drift.observe(tensor, basis) {
                        self.layer_acc(tensor).drift = Some(d);
                    }
                }
            }
            let acc = self.layer_acc(tensor);
            if let Some(n) = s.nrmse {
                acc.nrmse_sum += n;
                acc.nrmse_n += 1;
            }
            if let Some(c) = s.energy_coverage {
                acc.cover_sum += c;
                acc.cover_n += 1;
            }
            if let Some(r) = s.stable_rank {
                acc.srank_sum += r;
                acc.srank_n += 1;
            }
            acc.bytes += s.bytes;
            acc.energy += s.energy;
        }
        if let Some(cos) = self.stream.observe(ev.cid, ev.dense()) {
            let mut st = self.state.borrow_mut();
            for (l, &c) in cos.iter().enumerate() {
                self.acc[l].cos_sum += c;
                self.acc[l].cos_n += 1;
                if l < st.run_adj_sum.len() {
                    st.run_adj_sum[l] += c;
                }
            }
            st.run_adj_pairs += 1;
        }
    }

    fn on_apply(&mut self, _ev: &ApplyEvent) {
        // Publish this round's estimator means before the snapshot
        // freezes (gauges are last-write-wins, so absent values simply
        // carry the previous round forward).
        let Some(tel) = self.tel.as_deref() else { return };
        let agg = self.aggregate(0);
        let pairs: [(&'static str, Option<f64>); 7] = [
            ("diag.drift.mean_angle", agg.drift_mean_angle),
            ("diag.drift.chordal", agg.drift_chordal),
            ("diag.cosine.adjacent", agg.cosine),
            ("diag.fidelity.nrmse", agg.nrmse),
            ("diag.fidelity.energy_coverage", agg.energy_coverage),
            ("diag.fidelity.stable_rank", agg.stable_rank),
            ("diag.bytes_per_unit_energy", agg.bytes_per_unit_energy),
        ];
        for (key, v) in pairs {
            if let Some(v) = v {
                tel.gauge(key, v);
            }
        }
    }

    fn on_round(&mut self, round: usize, rec: &RoundRecord) {
        let timer = Telemetry::timer(self.tel.as_deref());
        let comms = self.comms.observe_round(rec.uplink_bytes, rec.train_loss);
        let mut agg = self.aggregate(round);
        agg.cum_uplink_bytes = Some(comms.cum_uplink_bytes);
        agg.loss_drop = comms.loss_drop;
        agg.bytes_per_loss = comms.bytes_per_loss;
        {
            let mut st = self.state.borrow_mut();
            let names = st.layer_names.clone();
            for (tensor, acc) in self.acc.iter().enumerate() {
                let touched = acc.drift.is_some()
                    || acc.cos_n > 0
                    || acc.nrmse_n > 0
                    || acc.bytes > 0;
                if !touched {
                    continue;
                }
                let layer = names
                    .get(tensor)
                    .cloned()
                    .unwrap_or_else(|| format!("t{tensor}"));
                st.rows.push(DiagRow {
                    round,
                    layer,
                    drift_mean_angle: acc.drift.as_ref().map(|d| d.mean_angle),
                    drift_max_angle: acc.drift.as_ref().map(|d| d.max_angle),
                    drift_chordal: acc.drift.as_ref().map(|d| d.chordal),
                    churn_dr: acc.drift.as_ref().map(|d| d.churn),
                    energy_coverage: mean(acc.cover_sum, acc.cover_n),
                    cosine: mean(acc.cos_sum, acc.cos_n),
                    nrmse: mean(acc.nrmse_sum, acc.nrmse_n),
                    stable_rank: mean(acc.srank_sum, acc.srank_n),
                    bytes_per_unit_energy: (acc.energy > 0.0)
                        .then(|| acc.bytes as f64 / acc.energy),
                    cum_uplink_bytes: None,
                    loss_drop: None,
                    bytes_per_loss: None,
                });
            }
            st.rows.push(agg);
        }
        if let Some(tel) = self.tel.as_deref() {
            if let Some(bpl) = comms.bytes_per_loss {
                tel.gauge("diag.comms.bytes_per_loss", bpl);
            }
        }
        for acc in &mut self.acc {
            *acc = LayerAcc::default();
        }
        if let Some(t) = timer {
            t.end(Phase::Diag, round as u64, None);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelKind;
    use crate::model::layer_table;

    fn record(round: usize, uplink: u64, loss: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: loss,
            test_accuracy: f64::NAN,
            test_loss: f64::NAN,
            uplink_bytes: uplink,
            downlink_bytes: 0,
            sim_time_s: 0.0,
            sim_clock_s: 0.0,
            sum_d: 0,
            survivors: vec![0],
            ext: None,
        }
    }

    fn arrive(probe: &mut DiagProbe, meta: &crate::model::ModelMeta, cid: usize, scale: f32) {
        let updates: Vec<LayerUpdate> = meta
            .layers
            .iter()
            .map(|l| LayerUpdate::Dense(vec![scale; l.size().min(8)]))
            .collect();
        probe.on_arrival(&ArrivalEvent {
            round: 0,
            cid,
            updates: &updates,
            meta,
            weight: 1.0,
            staleness: 0,
            vtime: 0.0,
            on_time: true,
        });
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.num_clients = 6;
        cfg.seed = 5;
        cfg
    }

    #[test]
    fn rows_accumulate_per_round_with_aggregate_last() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut probe = DiagProbe::new(&tiny_cfg(), DiagConfig { sample: 2 });
        let state = probe.state();
        let cid = state.borrow().sample[0];
        arrive(&mut probe, &meta, cid, 1.0);
        probe.on_round(0, &record(0, 100, 2.0));
        arrive(&mut probe, &meta, cid, 0.5);
        probe.on_round(1, &record(1, 100, 1.0));
        let st = state.borrow();
        assert_eq!(st.layer_names.len(), meta.layers.len());
        let r0 = st.rows_for_round(0);
        assert_eq!(r0.last().unwrap().layer, "*", "aggregate row last");
        assert_eq!(r0.last().unwrap().cum_uplink_bytes, Some(100));
        let r1 = st.rows_for_round(1);
        let agg = r1.last().unwrap();
        assert_eq!(agg.cum_uplink_bytes, Some(200), "cumulative bytes");
        assert!((agg.bytes_per_loss.unwrap() - 200.0).abs() < 1e-9);
        // Dense arrivals: exact-zero NRMSE, full coverage, and an
        // adjacent pair on round 1.
        assert_eq!(agg.nrmse, Some(0.0));
        assert_eq!(agg.energy_coverage, Some(1.0));
        assert!(agg.cosine.unwrap() > 0.99, "parallel updates");
        assert_eq!(st.run_adj_pairs, 1);
    }

    #[test]
    fn unsampled_clients_leave_no_trace() {
        let meta = layer_table(ModelKind::LeNet5);
        let mut probe = DiagProbe::new(&tiny_cfg(), DiagConfig { sample: 1 });
        let state = probe.state();
        let outsider = (0..6).find(|c| !state.borrow().sample.contains(c)).unwrap();
        arrive(&mut probe, &meta, outsider, 1.0);
        probe.on_round(0, &record(0, 50, 2.0));
        let st = state.borrow();
        let rows = st.rows_for_round(0);
        assert_eq!(rows.len(), 1, "only the aggregate row");
        assert!(rows[0].nrmse.is_none());
        assert_eq!(rows[0].cum_uplink_bytes, Some(50), "comms still tracked");
    }
}
