//! Sharded span buffers.
//!
//! Spans carry *two* clocks: host wall-time (microseconds since the
//! [`Telemetry`](super::Telemetry) epoch) for real compute cost, and the
//! scheduler's virtual clock (seconds) for simulated transport/compute
//! cost. Either side may be absent — a server-side `fold` has no virtual
//! duration (server work is free in the simulation's time model), and an
//! `uplink_transit` has no host duration (no real bytes move).
//!
//! The buffer is sharded by a key derived from the span itself (client id
//! when tagged, else round), never from the calling thread, so the same
//! span lands in the same shard at any worker count. Recording only ever
//! appends to a `Vec` behind a short-lived shard lock; nothing is read
//! back during a run, so tracing cannot perturb the computation — the
//! w1-vs-wN bit-identity tests in `rust/tests/telemetry.rs` lock this in.

use std::sync::Mutex;

/// Number of span shards (power of two; index is `key & (SHARDS-1)`).
const SHARDS: usize = 16;

/// The fixed span taxonomy, in round-lifecycle order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Materializing a virtual client lane — shard + RNG + compressor
    /// pair derived from `(seed, cid)` on first touch or after eviction
    /// (host).
    LaneMaterialize,
    /// Server encodes the global model for broadcast (host).
    BroadcastEncode,
    /// A client's local-train + compress lane (host), or its simulated
    /// compute draw (virtual).
    ClientCompress,
    /// A client's upload in flight on its link (virtual only).
    UplinkTransit,
    /// Server decodes one client's wire frame into `LayerUpdate`s (host).
    ServerDecode,
    /// An arrival whose client departed mid-flight (availability/churn
    /// plane): the upload is dropped undecoded, zero bytes charged, the
    /// slot released, the lane discarded (virtual only, zero duration —
    /// it marks the instant the server learned the client was gone).
    Fault,
    /// Folding decoded updates into the `ServerAggregator` (host).
    Fold,
    /// Materializing the aggregate and stepping the global model (host).
    Apply,
    /// Held-out evaluation of the stepped model (host).
    Eval,
    /// The diagnostics plane's per-round estimator pass — subspace
    /// drift, streaming correlation, fidelity, bytes-per-loss (host).
    Diag,
}

impl Phase {
    /// All phases, in lifecycle order.
    pub const ALL: [Phase; 10] = [
        Phase::LaneMaterialize,
        Phase::BroadcastEncode,
        Phase::ClientCompress,
        Phase::UplinkTransit,
        Phase::ServerDecode,
        Phase::Fault,
        Phase::Fold,
        Phase::Apply,
        Phase::Eval,
        Phase::Diag,
    ];

    /// Stable snake_case name (the `name` field in trace exports).
    pub fn name(self) -> &'static str {
        match self {
            Phase::LaneMaterialize => "lane_materialize",
            Phase::BroadcastEncode => "broadcast_encode",
            Phase::ClientCompress => "client_compress",
            Phase::UplinkTransit => "uplink_transit",
            Phase::ServerDecode => "server_decode",
            Phase::Fault => "fault",
            Phase::Fold => "fold",
            Phase::Apply => "apply",
            Phase::Eval => "eval",
            Phase::Diag => "diag",
        }
    }
}

/// One recorded span.
#[derive(Clone, Debug)]
pub struct Span {
    /// Which stage of the round lifecycle.
    pub phase: Phase,
    /// Round (sync/semisync) or apply/model-version context (async).
    pub round: u64,
    /// Client id when the span belongs to one lane; `None` = coordinator.
    pub client: Option<u32>,
    /// Host wall-time `(start_us, dur_us)` since the telemetry epoch.
    pub host: Option<(u64, u64)>,
    /// Virtual-clock `(start_s, end_s)`.
    pub virt: Option<(f64, f64)>,
}

impl Span {
    /// Deterministic sort key: independent of host timing and worker
    /// interleaving up to the host timestamps themselves.
    fn sort_key(&self) -> (u64, u32, Phase, u64, u64) {
        (
            self.round,
            self.client.map(|c| c + 1).unwrap_or(0),
            self.phase,
            self.virt.map(|(t, _)| t.to_bits()).unwrap_or(u64::MAX),
            self.host.map(|(t, _)| t).unwrap_or(u64::MAX),
        )
    }
}

/// Append-only sharded span store.
pub(crate) struct Tracer {
    shards: Vec<Mutex<Vec<Span>>>,
}

impl Tracer {
    pub(crate) fn new() -> Self {
        Tracer { shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect() }
    }

    /// Record one span. Shard choice depends only on the span's own tags.
    pub(crate) fn record(&self, span: Span) {
        let key = span.client.map(|c| c as u64).unwrap_or(span.round) as usize;
        self.shards[key & (SHARDS - 1)].lock().unwrap().push(span);
    }

    /// All spans so far, in a deterministic order (sorted by round, client,
    /// phase, then timestamps — host jitter can only reorder identical
    /// tags, never cross them).
    pub(crate) fn snapshot(&self) -> Vec<Span> {
        let mut all: Vec<Span> = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().iter().cloned());
        }
        all.sort_by(|a, b| a.sort_key().cmp(&b.sort_key()));
        all
    }

    /// Total spans recorded.
    pub(crate) fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(phase: Phase, round: u64, client: Option<u32>) -> Span {
        Span { phase, round, client, host: Some((round * 10, 5)), virt: None }
    }

    #[test]
    fn shard_choice_is_tag_deterministic() {
        let a = Tracer::new();
        let b = Tracer::new();
        // Same spans recorded in different orders land identically.
        let spans = vec![
            span(Phase::Fold, 0, None),
            span(Phase::ClientCompress, 0, Some(3)),
            span(Phase::ClientCompress, 0, Some(19)),
            span(Phase::Eval, 1, None),
        ];
        for s in &spans {
            a.record(s.clone());
        }
        for s in spans.iter().rev() {
            b.record(s.clone());
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        assert_eq!(sa.len(), sb.len());
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.phase, y.phase);
            assert_eq!(x.round, y.round);
            assert_eq!(x.client, y.client);
        }
    }

    #[test]
    fn snapshot_orders_by_round_then_client() {
        let t = Tracer::new();
        t.record(span(Phase::Eval, 1, None));
        t.record(span(Phase::ClientCompress, 0, Some(7)));
        t.record(span(Phase::BroadcastEncode, 0, None));
        let s = t.snapshot();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].round, 0);
        assert_eq!(s[0].client, None);
        assert_eq!(s[1].client, Some(7));
        assert_eq!(s[2].round, 1);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn phase_names_are_snake_case() {
        for p in Phase::ALL {
            let n = p.name();
            assert!(!n.is_empty());
            assert!(n.chars().all(|c| c.is_ascii_lowercase() || c == '_'));
        }
    }
}
