//! Streaming per-arrival probe API.
//!
//! [`Observer`] replaces the old sync-only `round_hook`: all three
//! schedulers call it at the same lifecycle points, so probes (similarity
//! heatmaps, per-arrival logging, experiment instrumentation) work
//! unchanged under semisync and async. The legacy dense
//! `RoundHookView` callback survives as an adapter in
//! [`crate::coordinator`] (`Simulation::set_round_hook`), which buffers
//! arrivals and replays them as a per-round batch.
//!
//! Lifecycle per scheduler:
//!
//! * **sync** — `on_dispatch` (the sampled survivors), one `on_arrival`
//!   per decoded upload (stragglers included, tagged `on_time = false`),
//!   `on_apply` when the round folds, `on_round` after the record lands.
//! * **semisync** — `on_dispatch` per round's fresh participants, one
//!   `on_arrival` per update folded by the deadline (rollovers from
//!   earlier rounds included, `staleness` = rounds since dispatch),
//!   `on_apply`/`on_round` as above.
//! * **async** — `on_dispatch` per slot refill batch, one `on_arrival`
//!   per folded update (`staleness` = model versions behind), `on_apply`
//!   and `on_round` at every k-th fold (one "round" = one apply).
//!
//! Observers only *watch*: they receive borrowed decoded updates and must
//! not assume any particular worker count produced them. Everything an
//! observer is handed is bit-identical at any `--workers` value.

use crate::compress::LayerUpdate;
use crate::metrics::RoundRecord;
use crate::model::ModelMeta;

/// A batch of clients entering training.
pub struct DispatchEvent<'a> {
    /// Round (sync/semisync) or apply index (async) at dispatch time.
    pub round: usize,
    /// Client ids dispatched in this batch.
    pub cids: &'a [usize],
    /// Virtual clock at dispatch.
    pub vtime: f64,
    /// Global-model version the broadcast was encoded from.
    pub model_version: u64,
}

/// One client's decoded update reaching the server.
pub struct ArrivalEvent<'a> {
    /// Round (sync/semisync) or in-progress apply index (async).
    pub round: usize,
    /// Client id.
    pub cid: usize,
    /// The decoded (still compressed-domain) per-layer updates.
    pub updates: &'a [LayerUpdate],
    /// Layer table for shaping [`ArrivalEvent::dense`].
    pub meta: &'a ModelMeta,
    /// Fold weight (0 for a sync straggler dropped by the deadline;
    /// staleness-discounted under async).
    pub weight: f64,
    /// Versions (async) or rounds (semisync rollover) behind at arrival.
    pub staleness: u64,
    /// Virtual clock at arrival.
    pub vtime: f64,
    /// False when the update arrived past the sync deadline (charged but
    /// not folded).
    pub on_time: bool,
}

impl ArrivalEvent<'_> {
    /// Densify the update (one flat `Vec<f32>` per layer) for probes that
    /// need raw gradients, e.g. [`crate::metrics::SimilarityProbe`].
    pub fn dense(&self) -> Vec<Vec<f32>> {
        self.updates.iter().map(|u| u.to_dense()).collect()
    }
}

/// The aggregate being applied to the global model.
pub struct ApplyEvent {
    /// Round (async: apply index).
    pub round: usize,
    /// Virtual clock at apply.
    pub vtime: f64,
    /// Updates folded into this aggregate.
    pub folded: usize,
    /// Total fold weight (the FedAvg normalizer).
    pub wtotal: f64,
}

/// Streaming run probe, called from all three schedulers.
///
/// Every method has a no-op default, so probes implement only what they
/// watch. Calls arrive on the coordinator/event-loop thread in
/// deterministic order.
pub trait Observer {
    /// A batch of clients was dispatched with a fresh broadcast.
    fn on_dispatch(&mut self, _ev: &DispatchEvent) {}
    /// A client's update was decoded server-side.
    fn on_arrival(&mut self, _ev: &ArrivalEvent) {}
    /// The buffered aggregate was applied to the global model.
    fn on_apply(&mut self, _ev: &ApplyEvent) {}
    /// A `RoundRecord` was finalized (after `on_apply` and eval).
    fn on_round(&mut self, _round: usize, _rec: &RoundRecord) {}
}
