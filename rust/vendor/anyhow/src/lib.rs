//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The workspace must build hermetically (no registry access), so this tiny
//! crate implements exactly the slice of anyhow's API the repository uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on both `Result`
//! and `Option`), and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Formatting semantics mirror anyhow where callers can observe them:
//! `{err}` prints the outermost message, `{err:#}` prints the full
//! `outer: inner: root` chain, and `{err:?}` prints the report form with a
//! `Caused by:` list. Like anyhow's, [`Error`] deliberately does **not**
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: context frames stored outermost-first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap the error in an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames[0])?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut frames = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Error { frames }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (with inline captures) or any
/// printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_only() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
    }

    #[test]
    fn alternate_display_full_chain() {
        let e = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
    }

    #[test]
    fn debug_report_lists_causes() {
        let e = Error::msg("root").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening config: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }
}
