//! Kernel offload: run GradESTC's compression hot path through the AOT
//! Pallas kernels (L1) instead of native Rust linalg, and verify both
//! give the same numbers at a real layer geometry.
//!
//! Demonstrates the artifact calling convention for the three compression
//! kernels (`project`, `reconstruct`, `sketch`) and cross-checks them
//! against `gradestc::linalg` — the same check `rust/tests/xla_runtime.rs`
//! automates, here in runnable-example form with timing.
//!
//! ```bash
//! make artifacts && cargo run --release --example kernel_offload
//! ```

use anyhow::Context;
use gradestc::linalg::{householder_qr, matmul, matmul_at_b, Mat};
use gradestc::runtime::{HostTensor, Runtime};
use gradestc::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")
        .context("artifacts missing — run `make artifacts` first")?;
    println!("PJRT platform: {}", rt.platform());

    // ResNetLite stage3 conv geometry — the paper's l=1152 layer.
    let entry = rt
        .manifest()
        .find_kernel("project", 1152, 128)
        .context("project kernel for 1152x128 not in manifest")?;
    let (l, m, k) = (entry.l, entry.m, entry.rank);
    println!("kernel geometry: l={l} m={m} k={k} (ResNet stage3 conv)");

    let mut rng = Pcg64::seeded(7);
    let (basis, _) = householder_qr(&Mat::randn(l, k, &mut rng));
    let g = Mat::randn(l, m, &mut rng);

    // --- XLA path -------------------------------------------------------
    let exe = rt.load(&entry.file)?;
    let inputs = [
        HostTensor::f32(basis.as_slice().to_vec(), &[l, k]),
        HostTensor::f32(g.as_slice().to_vec(), &[l, m]),
    ];
    let t0 = std::time::Instant::now();
    let iters = 50;
    let mut out = rt.call_exe(&exe, &inputs)?;
    for _ in 1..iters {
        out = rt.call_exe(&exe, &inputs)?;
    }
    let xla_us = t0.elapsed().as_micros() as f64 / iters as f64;

    // --- native path ------------------------------------------------------
    let t1 = std::time::Instant::now();
    let mut a_native = matmul_at_b(&basis, &g);
    let mut e_native = g.sub(&matmul(&basis, &a_native));
    for _ in 1..iters {
        a_native = matmul_at_b(&basis, &g);
        e_native = g.sub(&matmul(&basis, &a_native));
    }
    let native_us = t1.elapsed().as_micros() as f64 / iters as f64;

    // --- agreement --------------------------------------------------------
    let a_xla = Mat::from_vec(k, m, out[0].as_f32()?.to_vec());
    let e_xla = Mat::from_vec(l, m, out[1].as_f32()?.to_vec());
    let da = a_xla.max_abs_diff(&a_native);
    let de = e_xla.max_abs_diff(&e_native);
    println!("agreement: |ΔA|∞ = {da:.2e}, |ΔE|∞ = {de:.2e}");
    anyhow::ensure!(da < 1e-3 && de < 1e-3, "kernel/native mismatch");

    let flops = 2.0 * (2 * l * k * m) as f64; // MᵀG and M·A
    println!(
        "projection (A = MᵀG; E = G − MA), {iters} iters:\n\
         \tXLA (Pallas kernel via PJRT): {xla_us:>8.1} µs/iter  ({:.2} GFLOP/s)\n\
         \tnative rust linalg:           {native_us:>8.1} µs/iter  ({:.2} GFLOP/s)",
        flops / xla_us / 1e3,
        flops / native_us / 1e3,
    );
    println!("kernel_offload OK");
    Ok(())
}
