//! Non-IID showdown: the paper's central claim under label skew.
//!
//! GradESTC's client-local bases adapt to heterogeneous gradients where a
//! shared static basis (SVDFed-style) goes stale. This example runs
//! synth-CIFAR10 / ResNetLite at Dirichlet(0.1) — the paper's hardest
//! setting — for GradESTC, SVDFed and FedAvg and prints uplink/accuracy.
//!
//! ```bash
//! make artifacts && cargo run --release --example noniid_showdown [-- rounds]
//! ```

use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams,
};
use gradestc::coordinator::Simulation;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    println!(
        "non-IID showdown: synth-CIFAR10 / ResNetLite, Dirichlet(0.1), {rounds} rounds\n"
    );
    let mut rows = Vec::new();
    for (name, comp) in [
        ("fedavg", CompressorKind::None),
        ("svdfed", CompressorKind::SvdFed { k: 32, gamma: 0.5 }),
        (
            "gradestc",
            CompressorKind::GradEstc(GradEstcParams { k: 32, ..Default::default() }),
        ),
    ] {
        let mut cfg = ExperimentConfig::preset_table3(
            DatasetKind::SynthCifar10,
            DataDistribution::Dirichlet(0.1),
            comp,
            rounds,
            3,
        );
        cfg.name = format!("noniid-{name}");
        cfg.use_xla = have_artifacts;
        let mut sim = Simulation::build(cfg)?;
        let rep = sim.run_with_progress(|round, rec| {
            if round % 3 == 0 {
                println!(
                    "  [{name:<8}] round {round:>2}: acc {:>5.1}%  cum uplink {:>7.2} MB",
                    rec.test_accuracy * 100.0,
                    sim_cum(round, rec.uplink_bytes)
                );
            }
        })?;
        std::fs::create_dir_all("results")?;
        sim.recorder
            .write_csv(std::path::Path::new(&format!("results/noniid-{name}.csv")))?;
        rows.push((name, rep));
    }
    println!("\n=== Dirichlet(0.1) summary ===");
    for (name, r) in &rows {
        println!(
            "{name:<10} best acc {:>5.2}%   total uplink {:>8.3} MB",
            r.best_accuracy * 100.0,
            r.total_uplink as f64 / 1e6
        );
    }
    Ok(())
}

// Tiny helper so the progress line compiles without borrowing sim inside
// its own closure (cumulative uplink approximated per round).
fn sim_cum(round: usize, per_round: u64) -> f64 {
    (per_round * (round as u64 + 1)) as f64 / 1e6
}
