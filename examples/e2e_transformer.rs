//! End-to-end driver: federated training of a transformer language model
//! through the full three-layer stack.
//!
//! This is the repository's composition proof (DESIGN.md "End-to-end
//! validation"): the JAX-defined TinyTransformer (L2, with the Pallas-
//! kernel-backed compression path at L1) is AOT-lowered to HLO, loaded by
//! the Rust coordinator via PJRT, and trained federated on a synthetic
//! Markov byte corpus with GradESTC compressing the uplink. The loss curve
//! is logged to `results/e2e_transformer.csv` and summarized in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transformer [-- rounds]
//! ```

use gradestc::config::{
    CompressorKind, DataDistribution, DatasetKind, ExperimentConfig, GradEstcParams, ModelKind,
};
use gradestc::coordinator::Simulation;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(25);
    let cfg = ExperimentConfig {
        name: "e2e_transformer".into(),
        dataset: DatasetKind::TinyCorpus,
        model: ModelKind::TinyTransformer,
        distribution: DataDistribution::Iid,
        num_clients: 4,
        participation: 1.0,
        rounds,
        local_epochs: 1,
        batch_size: 16,
        lr: 0.1,
        samples_per_client: 96,
        test_samples: 128,
        eval_every: 1,
        threshold_frac: 0.95,
        compressor: CompressorKind::GradEstc(GradEstcParams {
            k: 16,
            coverage: 0.8,
            ..Default::default()
        }),
        seed: 42,
        use_xla: true, // the transformer is XLA-only: this IS the e2e proof
        artifacts_dir: "artifacts".into(),
        workers: 1, // XLA lanes run on the coordinator thread anyway
        net: gradestc::config::NetConfig::default(),
        sched: gradestc::config::SchedConfig::default(),
        backend: gradestc::config::BackendKind::Auto,
        lanes: gradestc::config::LaneConfig::default(),
    };
    println!(
        "e2e: TinyTransformer ({} params) on synthetic byte corpus, \
         {} clients x {} seqs, {} rounds, GradESTC k=16",
        gradestc::model::meta::layer_table(cfg.model).total_params(),
        cfg.num_clients,
        cfg.samples_per_client,
        cfg.rounds
    );
    let mut sim = Simulation::build(cfg)?;
    let t0 = std::time::Instant::now();
    let report = sim.run_with_progress(|round, rec| {
        println!(
            "round {round:>3}: train loss {:.4} | test loss {:.4} | \
             next-token acc {:>5.2}% | uplink {:>6.3} MB",
            rec.train_loss,
            rec.test_loss,
            rec.test_accuracy * 100.0,
            rec.uplink_bytes as f64 / 1e6
        );
    })?;
    std::fs::create_dir_all("results")?;
    sim.recorder.write_csv(std::path::Path::new("results/e2e_transformer.csv"))?;

    let first = sim.recorder.rounds().first().unwrap().train_loss;
    let last = sim.recorder.rounds().last().unwrap().train_loss;
    println!(
        "\nloss {first:.4} -> {last:.4} over {rounds} rounds in {:.1}s \
         | best next-token acc {:.2}% | total uplink {:.3} MB \
         | curve: results/e2e_transformer.csv",
        t0.elapsed().as_secs_f64(),
        report.best_accuracy * 100.0,
        report.total_uplink as f64 / 1e6
    );
    anyhow::ensure!(last < first, "loss did not decrease — e2e training failed");
    println!("E2E OK: all three layers compose (Pallas kernels -> JAX -> HLO -> PJRT -> rust FL loop)");
    Ok(())
}
