//! Quickstart: train a small federated model with GradESTC compression
//! and compare against uncompressed FedAvg.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the XLA artifacts when present (the canonical path) and falls back
//! to the native trainer otherwise, so the example always runs.

use gradestc::config::{CompressorKind, ExperimentConfig, GradEstcParams};
use gradestc::coordinator::Simulation;

fn main() -> anyhow::Result<()> {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let mut base = ExperimentConfig::preset_quickstart();
    base.use_xla = have_artifacts;
    base.rounds = 10;
    base.num_clients = 6;
    base.samples_per_client = 192;
    println!(
        "quickstart: synth-MNIST / LeNet-5, {} clients, {} rounds, backend: {}",
        base.num_clients,
        base.rounds,
        if base.use_xla { "XLA artifacts (PJRT)" } else { "native rust" }
    );

    let mut results = Vec::new();
    for (name, comp) in [
        ("fedavg   (no compression)", CompressorKind::None),
        (
            "gradestc (k=8)          ",
            CompressorKind::GradEstc(GradEstcParams { k: 8, ..Default::default() }),
        ),
    ] {
        let mut cfg = base.clone();
        cfg.name = name.split_whitespace().next().unwrap().to_string();
        cfg.compressor = comp;
        let mut sim = Simulation::build(cfg)?;
        let report = sim.run_with_progress(|round, rec| {
            println!(
                "  [{name}] round {round:>2}: loss {:.3}  acc {:>5.1}%  uplink {:>7.3} MB",
                rec.train_loss,
                rec.test_accuracy * 100.0,
                rec.uplink_bytes as f64 / 1e6
            );
        })?;
        results.push((name, report));
    }

    println!("\n=== summary ===");
    for (name, r) in &results {
        println!(
            "{name}: best acc {:>5.2}%  total uplink {:>7.3} MB",
            r.best_accuracy * 100.0,
            r.total_uplink as f64 / 1e6
        );
    }
    let (fa, ge) = (&results[0].1, &results[1].1);
    println!(
        "\nGradESTC used {:.1}x less uplink at {:+.2} pp accuracy",
        fa.total_uplink as f64 / ge.total_uplink as f64,
        (ge.best_accuracy - fa.best_accuracy) * 100.0
    );
    Ok(())
}
